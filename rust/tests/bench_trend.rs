//! Bench-trend smoke over the committed `BENCH_*.json` trajectory
//! files at the repo root: every snapshot must parse, the rankpar
//! snapshot must carry the schema-2 column set and the codec snapshot
//! the roofline column set (schema drift in an emitter without
//! regenerating the committed file fails here), and any *measured*
//! row must satisfy the acceptance floors (speedup regression
//! guards — including the codec hot path's 3x encode floor). Null
//! rows — the unmeasured scaffold the artifact-less authoring
//! container commits for artifact-dependent benches — are reported
//! and skipped, never failed; the codec bench needs no artifacts, so
//! its snapshot must always be measured.
//!
//! Runs everywhere: these tests read committed files only and need no
//! AOT artifacts.

use std::path::{Path, PathBuf};

use tpcc::util::json::Json;

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("rust/ sits inside the repo")
}

fn bench_files() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(repo_root())
        .expect("read repo root")
        .filter_map(|e| {
            let p = e.ok()?.path();
            let name = p.file_name()?.to_str()?;
            (name.starts_with("BENCH_") && name.ends_with(".json")).then_some(p)
        })
        .collect();
    out.sort();
    out
}

fn load(path: &Path) -> Json {
    let body = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    Json::parse(&body).unwrap_or_else(|e| panic!("parse {}: {e:#}", path.display()))
}

#[test]
fn every_committed_bench_snapshot_parses() {
    let files = bench_files();
    assert!(!files.is_empty(), "no BENCH_*.json at {}", repo_root().display());
    for f in files {
        let j = load(&f);
        assert!(
            j.get("bench").and_then(|b| b.as_str()).is_some(),
            "{}: missing \"bench\" name",
            f.display()
        );
        assert!(
            j.get("rows").and_then(|r| r.as_arr()).is_some(),
            "{}: missing \"rows\" array",
            f.display()
        );
    }
}

/// The rankpar row columns the emitter writes (schema 2). A committed
/// snapshot missing any of these means the emitter and the tracked
/// file drifted apart — regenerate the file.
const RANKPAR_COLUMNS: &[&str] = &[
    "tp",
    "batch",
    "seq",
    "workers",
    "seq_wall_s",
    "par_wall_s",
    "speedup",
    "traced_wall_s",
    "trace_overhead_pct",
    "phase_compute_s",
    "phase_codec_s",
    "phase_fabric_wait_s",
    "phase_link_s",
];

#[test]
fn rankpar_schema_and_speedup_floors() {
    let path = repo_root().join("BENCH_rankpar.json");
    let j = load(&path);
    assert_eq!(j.get("bench").and_then(|b| b.as_str()), Some("rankpar"));
    let schema = j.get("schema").and_then(|s| s.as_f64()).unwrap_or(0.0);
    assert!(schema >= 2.0, "rankpar snapshot predates schema 2 (got {schema})");

    let rows = j.get("rows").and_then(|r| r.as_arr()).expect("rows array");
    assert!(!rows.is_empty(), "rankpar snapshot has no rows");
    let mut measured = 0usize;
    for (i, row) in rows.iter().enumerate() {
        for col in RANKPAR_COLUMNS {
            assert!(
                row.get(col).is_some(),
                "row {i}: column {col:?} missing (emitter/schema drift — regenerate)"
            );
        }
        let tp = row.get("tp").and_then(|v| v.as_f64()).expect("tp is numeric") as usize;
        let (seq_w, par_w, speedup) = (
            row.get("seq_wall_s").and_then(|v| v.as_f64()),
            row.get("par_wall_s").and_then(|v| v.as_f64()),
            row.get("speedup").and_then(|v| v.as_f64()),
        );
        let (Some(seq_w), Some(par_w), Some(speedup)) = (seq_w, par_w, speedup) else {
            eprintln!("rankpar row {i} (tp={tp}): null measurements, skipping floors");
            continue;
        };
        measured += 1;
        // internal consistency: the stored ratio is the stored walls'
        let ratio = seq_w / par_w;
        assert!(
            (speedup - ratio).abs() / ratio < 0.05,
            "row {i}: speedup {speedup:.3} disagrees with seq/par {ratio:.3}"
        );
        // acceptance floors from the bench's tracked targets
        let floor = if tp >= 4 { 2.0 } else { 1.2 };
        assert!(
            speedup >= floor,
            "row {i} (tp={tp}): speedup {speedup:.2}x regressed below the {floor}x floor"
        );
        // recorder cost, when measured, stays under the bench's ceiling
        if let Some(pct) = row.get("trace_overhead_pct").and_then(|v| v.as_f64()) {
            assert!(
                pct < tpcc::bench::rankpar::DEFAULT_TRACE_OVERHEAD_PCT,
                "row {i}: committed trace overhead {pct:.2}% over the ceiling"
            );
        }
    }
    if measured == 0 {
        eprintln!("rankpar snapshot is an unmeasured scaffold (all rows null) — schema checked only");
    }
}

/// The codec-roofline row columns (`BENCH_codec.json`, schema 1) —
/// must match what `bench::codec::to_json` emits.
const CODEC_COLUMNS: &[&str] = &[
    "scheme",
    "block",
    "n_values",
    "fast_enc_gbps",
    "ref_enc_gbps",
    "enc_speedup",
    "fast_dec_gbps",
    "ref_dec_gbps",
    "dec_speedup",
    "memcpy_gbps",
];

#[test]
fn codec_schema_and_speedup_floors() {
    let path = repo_root().join("BENCH_codec.json");
    let j = load(&path);
    assert_eq!(j.get("bench").and_then(|b| b.as_str()), Some("codec"));
    let rows = j.get("rows").and_then(|r| r.as_arr()).expect("rows array");
    assert!(!rows.is_empty(), "codec snapshot has no rows");

    let mut measured = 0usize;
    let mut best_enc_speedup = 0.0f64;
    for (i, row) in rows.iter().enumerate() {
        for col in CODEC_COLUMNS {
            assert!(
                row.get(col).is_some(),
                "row {i}: column {col:?} missing (emitter/schema drift — regenerate)"
            );
        }
        let scheme = row.get("scheme").and_then(|v| v.as_str()).expect("scheme is a string");
        // every committed scheme must still parse (grid drift guard)
        tpcc::mxfmt::MxScheme::parse(scheme)
            .unwrap_or_else(|e| panic!("row {i}: scheme {scheme:?} no longer parses: {e:#}"));
        let (fe, re, spd) = (
            row.get("fast_enc_gbps").and_then(|v| v.as_f64()),
            row.get("ref_enc_gbps").and_then(|v| v.as_f64()),
            row.get("enc_speedup").and_then(|v| v.as_f64()),
        );
        let (Some(fe), Some(re), Some(spd)) = (fe, re, spd) else {
            eprintln!("codec row {i} ({scheme}): null measurements, skipping floors");
            continue;
        };
        measured += 1;
        // internal consistency: the stored speedup is the stored rates'
        let ratio = fe / re;
        assert!(
            (spd - ratio).abs() / ratio < 0.05,
            "row {i} ({scheme}): enc_speedup {spd:.3} disagrees with fast/ref {ratio:.3}"
        );
        // the fast path must never lose to the reference it replaced
        assert!(
            spd >= 1.0,
            "row {i} ({scheme}): fast encode is SLOWER than the reference ({spd:.2}x)"
        );
        if let Some(d) = row.get("dec_speedup").and_then(|v| v.as_f64()) {
            assert!(
                d >= 1.0,
                "row {i} ({scheme}): fast decode is SLOWER than the reference ({d:.2}x)"
            );
        }
        // a committed rate can't exceed the host's own memcpy ceiling
        if let Some(ceiling) = row.get("memcpy_gbps").and_then(|v| v.as_f64()) {
            assert!(
                fe <= ceiling * 1.05,
                "row {i} ({scheme}): encode {fe:.2} GB/s beats the memcpy ceiling {ceiling:.2}"
            );
        }
        best_enc_speedup = best_enc_speedup.max(spd);
    }
    // unlike rankpar, the codec bench needs no AOT artifacts — there
    // is never a reason to commit a null scaffold for this file
    assert!(measured > 0, "BENCH_codec.json must carry measured rows (run `tpcc bench --codec`)");
    // the acceptance floor: the fused hot path is only worth its
    // complexity if at least one scheme x block point encodes >= 3x
    // the scalar reference
    assert!(
        best_enc_speedup >= 3.0,
        "no measured row reaches the 3x encode-speedup floor (best {best_enc_speedup:.2}x)"
    );
}
