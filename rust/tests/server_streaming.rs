//! Streaming `/generate` tests against a stub coordinator — no AOT
//! artifacts needed. The stub plays the engine side of the submission
//! channel, dripping tokens on a schedule, so these pin the HTTP
//! streaming substrate: chunked framing, first-token-before-completion,
//! and the per-token (not per-request) socket deadline.

use std::time::{Duration, Instant};

use tpcc::coordinator::{CoordinatorHandle, GenResponse, StreamEvent};
use tpcc::server::{http_post_stream, Server};
use tpcc::util::json::Json;

/// Spawn a stub engine that answers every streaming submission with
/// `n_tokens` one-byte tokens spaced `gap` apart, then a Done event.
fn stub_engine(n_tokens: usize, gap: Duration) -> CoordinatorHandle {
    let (handle, rx) = CoordinatorHandle::stubbed();
    std::thread::spawn(move || {
        for (req, _reply, stream) in rx.iter() {
            let Some(events) = stream else { continue };
            for i in 0..n_tokens {
                if events
                    .send(StreamEvent::Token { index: i, token: b'a' as i32, text: "a".into() })
                    .is_err()
                {
                    break;
                }
                std::thread::sleep(gap);
            }
            let _ = events.send(StreamEvent::Done(GenResponse {
                id: 1,
                text: "a".repeat(n_tokens),
                prompt_tokens: req.prompt.len(),
                new_tokens: n_tokens,
                ttft_s: 0.001,
                e2e_s: gap.as_secs_f64() * n_tokens as f64,
                tpot_s: gap.as_secs_f64(),
                queue_wait_s: 0.0,
                virtual_prefill_s: 0.0,
            }));
        }
    });
    handle
}

fn serve_one(handle: CoordinatorHandle, io_timeout: Duration) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", handle)
        .unwrap()
        .with_pool(2, 8)
        .with_io_timeout(io_timeout);
    let addr = server.local_addr().unwrap().to_string();
    let join = std::thread::spawn(move || server.serve_n(1).unwrap());
    (addr, join)
}

#[test]
fn first_token_arrives_before_the_stream_completes() {
    // 6 tokens at 120ms: total generation (~720ms) far exceeds the
    // 400ms io timeout — per-token deadline re-arm keeps it alive, and
    // the first token must land long before the done line
    let handle = stub_engine(6, Duration::from_millis(120));
    let (addr, join) = serve_one(handle, Duration::from_millis(400));
    let mut stamps: Vec<Instant> = Vec::new();
    let (status, chunks) = http_post_stream(
        &addr,
        "/generate",
        r#"{"prompt":"hi","max_tokens":6,"stream":true}"#,
        |_| stamps.push(Instant::now()),
    )
    .unwrap();
    join.join().unwrap();
    assert_eq!(status, 200);
    assert_eq!(chunks.len(), 7, "6 token lines + 1 done line: {chunks:?}");
    let first = Json::parse(chunks[0].trim()).unwrap();
    assert_eq!(first.get("index").and_then(Json::as_f64), Some(0.0));
    assert_eq!(first.get("text").and_then(Json::as_str), Some("a"));
    assert!(first.get("done").is_none());
    let last = Json::parse(chunks.last().unwrap().trim()).unwrap();
    assert_eq!(last.get("done").and_then(Json::as_bool), Some(true));
    assert_eq!(last.get("new_tokens").and_then(Json::as_f64), Some(6.0));
    // the whole point of streaming: the first token arrived well before
    // the generation finished, not alongside it
    let lead = stamps.last().unwrap().duration_since(stamps[0]);
    assert!(
        lead >= Duration::from_millis(400),
        "first token should lead the done line by the generation time, got {lead:?}"
    );
}

#[test]
fn slow_drain_client_is_not_killed_mid_stream() {
    // tokens arrive on a schedule while the client also drains slowly:
    // total stream time (~1s) is far beyond the 250ms io timeout, which
    // must apply per token write, never to the whole response
    let handle = stub_engine(8, Duration::from_millis(60));
    let (addr, join) = serve_one(handle, Duration::from_millis(250));
    let (status, chunks) = http_post_stream(
        &addr,
        "/generate",
        r#"{"prompt":"hi","stream":true}"#,
        |_| std::thread::sleep(Duration::from_millis(70)),
    )
    .unwrap();
    join.join().unwrap();
    assert_eq!(status, 200);
    assert_eq!(chunks.len(), 9, "a slow-drain client must still see every chunk");
    assert!(chunks.last().unwrap().contains("\"done\":true"));
}

#[test]
fn engine_stall_surfaces_as_in_band_error() {
    // a dead engine (stub receiver dropped, so no events ever arrive)
    // must terminate the stream with an in-band error line within the io
    // timeout instead of wedging the worker
    let (handle, rx) = CoordinatorHandle::stubbed();
    drop(rx);
    let (addr, join) = serve_one(handle, Duration::from_millis(200));
    let t0 = Instant::now();
    let (status, chunks) =
        http_post_stream(&addr, "/generate", r#"{"prompt":"hi","stream":true}"#, |_| {}).unwrap();
    join.join().unwrap();
    assert_eq!(status, 200);
    assert!(t0.elapsed() < Duration::from_secs(5));
    assert_eq!(chunks.len(), 1);
    assert!(chunks[0].contains("error"), "got: {chunks:?}");
}

#[test]
fn non_streaming_generate_still_answers_plain_json() {
    // "stream": false (or absent) keeps the old single-body contract;
    // against a stub with no engine the reply channel dies and the
    // server answers 500 with a JSON error
    let (handle, rx) = CoordinatorHandle::stubbed();
    drop(rx);
    let server = Server::bind("127.0.0.1:0", handle).unwrap().with_pool(1, 4);
    let addr = server.local_addr().unwrap().to_string();
    let join = std::thread::spawn(move || server.serve_n(1).unwrap());
    let (status, body) =
        tpcc::server::http_post(&addr, "/generate", r#"{"prompt":"hi"}"#).unwrap();
    join.join().unwrap();
    assert_eq!(status, 500);
    assert!(body.contains("error"));
}
