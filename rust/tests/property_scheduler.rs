//! Property tests over the coordinator's scheduling + session state
//! machines and the analytic perf model (no artifacts needed).

use tpcc::coordinator::scheduler::{admit_count, pick_prefill_bucket, should_flush};
use tpcc::coordinator::session::{Session, SessionState};
use tpcc::interconnect::HwProfile;
use tpcc::model::perf_model::{Scenario, LLAMA2_13B, LLAMA2_70B, LLAMA2_7B};
use tpcc::mxfmt::baselines::Fp16;
use tpcc::mxfmt::{MxCodec, MxScheme};
use tpcc::util::rng::Rng;

const BB: &[usize] = &[1, 8];
const SB: &[usize] = &[1, 16, 64, 128, 256];

/// Bucket selection must always cover every prompt, never pick the
/// decode bucket, and be minimal among covering buckets.
#[test]
fn prop_bucket_selection_sound_and_minimal() {
    let mut rng = Rng::new(11);
    for _ in 0..500 {
        let n = 1 + rng.below(8);
        let lens: Vec<usize> = (0..n).map(|_| 1 + rng.below(256)).collect();
        let Some((b, s)) = pick_prefill_bucket(&lens, BB, SB) else {
            panic!("prompts <= 256 must always fit: {lens:?}");
        };
        let maxlen = *lens.iter().max().unwrap();
        assert!(s >= maxlen && s > 1, "{lens:?} -> ({b},{s})");
        assert!(b >= lens.len());
        // minimality
        for &s2 in SB {
            if s2 > 1 && s2 >= maxlen {
                assert!(s <= s2);
            }
        }
        for &b2 in BB {
            if b2 >= lens.len() {
                assert!(b <= b2);
            }
        }
    }
}

/// Admission never exceeds free slots, queue depth, or the batch cap,
/// and is work-conserving (admits something whenever it can).
#[test]
fn prop_admission_bounds() {
    let mut rng = Rng::new(22);
    for _ in 0..1000 {
        let queued = rng.below(32);
        let free = rng.below(16);
        let cap = 1 + rng.below(8);
        let n = admit_count(queued, free, cap);
        assert!(n <= queued && n <= free && n <= cap);
        if queued > 0 && free > 0 {
            assert!(n > 0, "work-conserving: q={queued} f={free} c={cap}");
        }
    }
}

/// Flush policy: full batches always flush; empty queues never do;
/// waiting long enough always flushes a non-empty queue.
#[test]
fn prop_flush_policy() {
    let mut rng = Rng::new(33);
    for _ in 0..1000 {
        let wait = rng.f64() * 0.2;
        let count = rng.below(9);
        let maxb = 1 + rng.below(8);
        let maxw = 0.05;
        let f = should_flush(wait, count, maxb, maxw);
        if count == 0 {
            assert!(!f);
        }
        if count >= maxb {
            assert!(f);
        }
        if count > 0 && wait >= maxw {
            assert!(f);
        }
    }
}

/// Session state machine: tokens only accumulate, positions advance by
/// one per decode, ttft <= e2e, completion is terminal and exact.
#[test]
fn prop_session_lifecycle() {
    let mut rng = Rng::new(44);
    for _ in 0..300 {
        let plen = 1 + rng.below(64);
        let maxnew = 1 + rng.below(32);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(256) as i32).collect();
        let mut s = Session::new(1, prompt, maxnew);
        assert_eq!(s.state, SessionState::Queued);
        s.record_first_token(rng.below(256) as i32);
        let mut steps = 1usize;
        while !s.is_done() {
            let before = s.pos;
            s.record_token(rng.below(256) as i32);
            steps += 1;
            assert_eq!(s.pos, before + 1);
            assert!(steps <= maxnew, "session over-generates");
        }
        assert_eq!(s.generated.len(), maxnew);
        assert_eq!(s.pos, plen + maxnew - 1);
        assert!(s.ttft().unwrap() <= s.e2e().unwrap());
    }
}

/// Perf model monotonicities the Table 3 story depends on.
#[test]
fn prop_perf_model_monotone() {
    let l4 = HwProfile::by_name("l4").unwrap();
    let mx = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
    for model in [LLAMA2_7B, LLAMA2_13B, LLAMA2_70B] {
        // longer inputs take longer, both paths
        let mut prev_u = 0.0;
        let mut prev_c = 0.0;
        for seq in [64usize, 128, 256, 512] {
            let sc = Scenario { model, profile: l4, tp: 8, batch: 2, seq };
            let u = sc.ttft(&Fp16).total();
            let c = sc.ttft(&mx).total();
            assert!(u > prev_u && c > prev_c, "{} seq {seq}", model.name);
            prev_u = u;
            prev_c = c;
        }
        // more TP shrinks compute but grows collective count cost per
        // worker: compute term must be monotone decreasing
        let mut prev_compute = f64::INFINITY;
        for tp in [2usize, 4, 8] {
            let sc = Scenario { model, profile: l4, tp, batch: 2, seq: 128 };
            let b = sc.ttft(&Fp16);
            assert!(b.compute_s < prev_compute);
            prev_compute = b.compute_s;
        }
    }
}

/// Compressed wire bytes are always ~3.76x smaller than fp16 for the
/// paper scheme, at any scenario size.
#[test]
fn prop_compression_ratio_constant() {
    let l4 = HwProfile::by_name("l4").unwrap();
    let mx = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
    let mut rng = Rng::new(55);
    for _ in 0..50 {
        let batch = 1 + rng.below(16);
        let seq = 32 * (1 + rng.below(16));
        let sc = Scenario { model: LLAMA2_13B, profile: l4, tp: 4, batch, seq };
        let u = sc.ttft(&Fp16);
        let c = sc.ttft(&mx);
        let ratio = u.wire_bytes as f64 / c.wire_bytes as f64;
        assert!((ratio - 16.0 / 4.25).abs() < 0.01, "ratio {ratio}");
    }
}
