//! Rank-thread runtime equivalence: the parallel execution core must be
//! **bit-identical** to the sequential reference path — logits, sampled
//! tokens, wire bytes, per-site stats, and `/metrics` policy counters —
//! across TP degrees and policies. Engine-level tests need AOT
//! artifacts (self-skip without them, like the other engine suites);
//! the knob/assignment tests run everywhere.

use tpcc::model::weights::Weights;
use tpcc::runtime::Runtime;
use tpcc::tp::{BatchKv, EngineOptions, RankThreads, TpEngine};

const SCHEME: &str = "fp4_e2m1_b32_e8m0";

fn artifacts() -> Option<std::path::PathBuf> {
    let d = tpcc::artifacts_dir();
    d.join("manifest.json").exists().then_some(d)
}

fn make_engine(
    root: &std::path::Path,
    tp: usize,
    compress: &str,
    policy: &str,
    rank_threads: RankThreads,
) -> TpEngine {
    let rt = Runtime::load(root).unwrap();
    let weights = Weights::load(&root.join("weights/nano")).unwrap();
    let opts = EngineOptions::new("nano", tp)
        .with_compress(compress)
        .with_policy(policy)
        .with_rank_threads(rank_threads);
    TpEngine::new(rt, &weights, opts).unwrap()
}

/// TP degrees with exported prefill stage programs for this bucket.
fn available_degrees(root: &std::path::Path) -> Vec<usize> {
    let rt = Runtime::load(root).unwrap();
    [1usize, 2, 4, 8]
        .into_iter()
        .filter(|tp| {
            *tp == 1
                || rt
                    .manifest
                    .by_name(&format!("nano/attn_prefill_tp{tp}_b1_s128"))
                    .is_some()
        })
        .collect()
}

fn prompt() -> Vec<i32> {
    (0..128).map(|i| (i * 13 + 5) % 256).collect()
}

/// Run one prefill on both cores and assert everything observable is
/// identical; returns both engines for follow-on checks.
fn assert_prefill_equivalent(
    root: &std::path::Path,
    tp: usize,
    policy: &str,
) -> (TpEngine, TpEngine) {
    let toks = prompt();
    let mut seq = make_engine(root, tp, SCHEME, policy, RankThreads::Off);
    let mut par = make_engine(root, tp, SCHEME, policy, RankThreads::Auto);
    if tp > 1 {
        assert!(par.rank_workers() >= 1, "tp={tp}: pool did not spawn");
    }
    let (l_seq, t_seq) = seq.prefill(&toks, 1, 128, &[0], None).unwrap();
    let (l_par, t_par) = par.prefill(&toks, 1, 128, &[0], None).unwrap();
    assert_eq!(l_seq, l_par, "tp={tp} policy={policy:?}: logits not bit-identical");
    assert_eq!(t_seq.wire_bytes, t_par.wire_bytes, "tp={tp} {policy:?}: wire bytes differ");
    assert_eq!(t_seq.raw_bytes, t_par.raw_bytes, "tp={tp} {policy:?}: raw bytes differ");
    assert_eq!(t_seq.algo, t_par.algo, "tp={tp} {policy:?}: planned algo differs");
    // per-site telemetry identical (calls, wire, raw per site)
    let s_stats: Vec<(u64, u64, u64)> =
        seq.site_stats().iter().map(|s| (s.calls, s.wire_bytes, s.raw_bytes)).collect();
    let p_stats: Vec<(u64, u64, u64)> =
        par.site_stats().iter().map(|s| (s.calls, s.wire_bytes, s.raw_bytes)).collect();
    assert_eq!(s_stats, p_stats, "tp={tp} {policy:?}: site stats differ");
    // the /metrics policy counter rollups agree exactly
    assert_eq!(
        seq.policy_metrics(),
        par.policy_metrics(),
        "tp={tp} {policy:?}: policy metrics differ"
    );
    (seq, par)
}

#[test]
fn parallel_matches_sequential_across_tp_degrees() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let degrees = available_degrees(&root);
    assert!(degrees.contains(&2), "nano tp=2 artifacts missing");
    for tp in degrees {
        let (_seq, par) = assert_prefill_equivalent(&root, tp, "");
        if tp > 1 {
            // every rank accumulated real busy time on the workers
            let gauges = par.rank_metrics();
            for r in 0..tp {
                let key = format!("rank{r}_compute_busy_s");
                let v = gauges.iter().find(|(k, _)| *k == key).map(|(_, v)| *v).unwrap();
                assert!(v > 0.0, "tp={tp}: {key} never accumulated");
            }
        }
    }
}

#[test]
fn parallel_matches_sequential_for_selective_policies() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    for policy in ["paper", "auto", "attn=none;decode=none"] {
        assert_prefill_equivalent(&root, 2, policy);
    }
}

#[test]
fn parallel_decode_and_kv_match_sequential() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let toks = prompt();
    let mut seq = make_engine(&root, 2, SCHEME, "", RankThreads::Off);
    let mut par = make_engine(&root, 2, SCHEME, "", RankThreads::Fixed(2));
    let cfg = seq.cfg.clone();
    let mut kv_seq = BatchKv::new(&cfg, 2, 1);
    let mut kv_par = BatchKv::new(&cfg, 2, 1);
    let (_, _) = seq.prefill(&toks, 1, 128, &[0], Some(&mut kv_seq)).unwrap();
    let (_, _) = par.prefill(&toks, 1, 128, &[0], Some(&mut kv_par)).unwrap();
    // the KV contents the workers wrote must match the sequential writes
    for rank in 0..2 {
        for layer in 0..cfg.n_layers {
            assert_eq!(
                kv_seq.k_at(rank, layer),
                kv_par.k_at(rank, layer),
                "kv k differs at rank {rank} layer {layer}"
            );
            assert_eq!(
                kv_seq.v_at(rank, layer),
                kv_par.v_at(rank, layer),
                "kv v differs at rank {rank} layer {layer}"
            );
        }
    }
    // greedy decode continues identically for a few steps
    let v = cfg.vocab;
    let mut tok_seq = 1i32;
    let mut tok_par = 1i32;
    for step in 0..3 {
        let pos = 128 + step;
        let (ls, _) = seq.decode(&[tok_seq], &[pos], &mut kv_seq).unwrap();
        let (lp, _) = par.decode(&[tok_par], &[pos], &mut kv_par).unwrap();
        assert_eq!(ls, lp, "decode logits diverge at step {step}");
        let argmax = |l: &[f32]| {
            (0..v)
                .max_by(|&a, &b| l[a].partial_cmp(&l[b]).unwrap())
                .unwrap() as i32
        };
        tok_seq = argmax(&ls);
        tok_par = argmax(&lp);
        assert_eq!(tok_seq, tok_par, "sampled tokens diverge at step {step}");
    }
}

#[test]
fn policy_rebind_reaches_the_worker_pool() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let toks = prompt();
    let mut seq = make_engine(&root, 2, SCHEME, "", RankThreads::Off);
    let mut par = make_engine(&root, 2, SCHEME, "", RankThreads::Auto);
    for policy in ["mlp=none", "uniform:fp5_e2m2_b16_e8m0", ""] {
        seq.set_policy(policy).unwrap();
        par.set_policy(policy).unwrap();
        let (ls, ts) = seq.prefill(&toks, 1, 128, &[0], None).unwrap();
        let (lp, tp_) = par.prefill(&toks, 1, 128, &[0], None).unwrap();
        assert_eq!(ls, lp, "policy {policy:?}: logits differ after rebind");
        assert_eq!(ts.wire_bytes, tp_.wire_bytes, "policy {policy:?}: wire bytes differ");
    }
}

/// End-to-end serving equality: greedy generations through the full
/// coordinator must be byte-identical between the two cores.
#[test]
fn coordinator_generations_identical_across_cores() {
    use tpcc::coordinator::{spawn, CoordinatorOptions, GenRequest};

    let Some(_) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let spawn_with = |rank_threads: RankThreads| {
        spawn(
            move || {
                let root = tpcc::artifacts_dir();
                let rt = Runtime::load(&root)?;
                let weights = Weights::load(&root.join("weights/nano"))?;
                TpEngine::new(
                    rt,
                    &weights,
                    EngineOptions::new("nano", 2)
                        .with_compress(SCHEME)
                        .with_rank_threads(rank_threads),
                )
            },
            CoordinatorOptions::default(),
        )
        .unwrap()
    };
    let (h_seq, j_seq) = spawn_with(RankThreads::Off);
    let (h_par, j_par) = spawn_with(RankThreads::Auto);
    let req = GenRequest {
        prompt: "The parish church of ".into(),
        max_new_tokens: 12,
        greedy: true,
        stop_token: -1,
    };
    let a = h_seq.generate(req.clone()).unwrap();
    let b = h_par.generate(req).unwrap();
    assert_eq!(a.text, b.text, "sampled tokens differ between cores");
    assert_eq!(a.new_tokens, b.new_tokens);
    for (h, j) in [(h_seq, j_seq), (h_par, j_par)] {
        h.shutdown();
        drop(h);
        j.join().unwrap().unwrap();
    }
}

/// In-flight batching must not change what any request generates. A
/// burst of requests served through the continuous batcher with a KV
/// pool sized to force preemption + swap-restore (and one prompt long
/// enough for the chunked-prefill path where its executables exist)
/// must produce text byte-identical to the one-at-a-time sequential
/// reference.
#[test]
fn continuous_batching_preserves_generations_under_preemption() {
    use tpcc::coordinator::{spawn, CoordinatorOptions, GenRequest};

    let Some(_) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let spawn_with = |copts: CoordinatorOptions, rank_threads: RankThreads| {
        spawn(
            move || {
                let root = tpcc::artifacts_dir();
                let rt = Runtime::load(&root)?;
                let weights = Weights::load(&root.join("weights/nano"))?;
                TpEngine::new(
                    rt,
                    &weights,
                    EngineOptions::new("nano", 2)
                        .with_compress(SCHEME)
                        .with_rank_threads(rank_threads),
                )
            },
            copts,
        )
        .unwrap()
    };
    let mut reqs: Vec<GenRequest> = (0..8)
        .map(|i| GenRequest {
            prompt: format!("The parish church of Saint Number {i} "),
            max_new_tokens: 24 + (i % 4),
            greedy: true,
            stop_token: -1,
        })
        .collect();
    // a >128-token prompt exercises chunked prefill when the (1, s)
    // KV-aware attn executables are exported, and the whole-prompt
    // fallback otherwise — the output must be identical either way
    reqs[3].prompt = "All Saints ".repeat(14);

    // one-at-a-time sequential-core reference
    let (h_ref, j_ref) = spawn_with(CoordinatorOptions::default(), RankThreads::Off);
    let reference: Vec<String> =
        reqs.iter().map(|r| h_ref.generate(r.clone()).unwrap().text).collect();
    h_ref.shutdown();
    drop(h_ref);
    j_ref.join().unwrap().unwrap();

    // stressed continuous batcher: 16 blocks of 16 tokens is exactly one
    // max-seq sequence (the pool floor), so concurrent sessions crossing
    // block boundaries must preempt and restore to finish
    let copts = CoordinatorOptions {
        decode_batch: 8,
        kv_block: 16,
        kv_pool_blocks: Some(16),
        ..Default::default()
    };
    let (h, j) = spawn_with(copts, RankThreads::Auto);
    let pending: Vec<_> = reqs.iter().map(|r| h.submit(r.clone())).collect();
    let texts: Vec<String> =
        pending.into_iter().map(|rx| rx.recv().unwrap().text).collect();
    assert_eq!(texts, reference, "continuous batching changed a generation");
    assert!(
        h.metrics.preemptions_total.get() >= 1,
        "pool of 16 blocks never forced a preemption"
    );
    assert_eq!(h.metrics.requests_completed.get(), 8);
    h.shutdown();
    drop(h);
    j.join().unwrap().unwrap();
}

/// Turning the span recorder on must not perturb results: traced
/// parallel logits stay bit-identical to the untraced sequential
/// reference, and the drained timeline carries compute and fabric
/// spans from the rank workers.
#[test]
fn tracing_enabled_keeps_logits_bit_identical() {
    use tpcc::obs::Cat;

    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let toks = prompt();
    let mut seq = make_engine(&root, 2, SCHEME, "", RankThreads::Off);
    let mut par = make_engine(&root, 2, SCHEME, "", RankThreads::Auto);
    // sequential reference runs untraced (recorder off by default)
    let (l_seq, _) = seq.prefill(&toks, 1, 128, &[0], None).unwrap();
    par.tracer().set_enabled(true);
    let (l_par, _) = par.prefill(&toks, 1, 128, &[0], None).unwrap();
    par.tracer().set_enabled(false);
    assert_eq!(l_seq, l_par, "tracing changed the parallel logits");
    let dump = par.tracer().drain();
    assert!(!dump.spans.is_empty(), "traced prefill recorded no spans");
    assert!(dump.spans.iter().any(|s| s.cat == Cat::Compute), "no compute spans");
    assert!(
        dump.spans.iter().any(|s| s.cat == Cat::Fabric),
        "no fabric exchange spans from the rank workers"
    );
    // the phase gauges accumulated real wall time
    let p = par.tracer().phase_snapshot();
    assert!(p[0] > 0.0, "phase_compute_s never accumulated: {p:?}");
}

// ---- knob / assignment sanity (no artifacts needed) ----

#[test]
fn rank_threads_knob_parses_and_resolves() {
    assert_eq!(RankThreads::parse("off").unwrap(), RankThreads::Off);
    assert_eq!(RankThreads::parse("sequential").unwrap(), RankThreads::Off);
    assert_eq!(RankThreads::parse("auto").unwrap(), RankThreads::Auto);
    assert_eq!(RankThreads::parse("").unwrap(), RankThreads::Auto);
    assert_eq!(RankThreads::parse("2").unwrap(), RankThreads::Fixed(2));
    assert_eq!(RankThreads::parse("0").unwrap(), RankThreads::Off);
    assert!(RankThreads::parse("fast").is_err());
    // off and tp=1 never spawn; fixed clamps to tp; auto caps at cores
    assert_eq!(RankThreads::Off.workers(8), 0);
    assert_eq!(RankThreads::Auto.workers(1), 0);
    assert_eq!(RankThreads::Fixed(9).workers(4), 4);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    assert_eq!(RankThreads::Auto.workers(64), 64.min(cores));
    assert!(RankThreads::Auto.workers(2) >= 1);
}

#[test]
fn rank_ownership_is_contiguous_and_leader_first() {
    use tpcc::tp::rank::owned_ranks;
    for tp in [2usize, 4, 8] {
        for workers in 1..=tp {
            let mut all = Vec::new();
            for w in 0..workers {
                all.extend(owned_ranks(tp, workers, w));
            }
            assert_eq!(all, (0..tp).collect::<Vec<_>>());
            assert_eq!(owned_ranks(tp, workers, 0)[0], 0);
        }
    }
}
