//! Perplexity-harness integration: the orderings the paper's tables rest
//! on must hold on the real trained models through the real engine.

use tpcc::eval::{perplexity, EvalOptions};
use tpcc::model::weights::Weights;
use tpcc::runtime::Runtime;
use tpcc::tp::{EngineOptions, TpEngine};

fn have_artifacts() -> bool {
    tpcc::artifacts_dir().join("manifest.json").exists()
}

fn engine(model: &str, tp: usize) -> TpEngine {
    let root = tpcc::artifacts_dir();
    let rt = Runtime::load(&root).unwrap();
    let weights = Weights::load(&root.join("weights").join(model)).unwrap();
    TpEngine::new(rt, &weights, EngineOptions::new(model, tp)).unwrap()
}

fn corpus(split: &str) -> String {
    std::fs::read_to_string(
        tpcc::artifacts_dir().join("weights").join(format!("corpus_{split}.txt")),
    )
    .unwrap()
}

const OPT: EvalOptions = EvalOptions { seq: 128, batch: 8, max_tokens: 1024, stride: 128 };

#[test]
fn model_learned_something() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut eng = engine("nano", 2);
    let text = corpus("test");
    let r = perplexity(&mut eng, &text, OPT).unwrap();
    // byte-level uniform is 256; the trained model must be far below
    assert!(r.ppl() < 8.0, "nano test ppl {} — training failed?", r.ppl());
    assert!(r.ppl() > 1.01);
    assert_eq!(r.tokens, 1024);
}

#[test]
fn dtype_degradation_ordering_holds() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // Table 1's core ordering on the real model: FP5 <= FP4 <= FP3 damage
    let mut eng = engine("nano", 2);
    let text = corpus("train");
    let base = perplexity(&mut eng, &text, OPT).unwrap();
    let mut incs = Vec::new();
    for spec in ["fp5_e2m2_b32_e8m0", "fp4_e2m1_b32_e8m0", "fp3_e1m1_b32_e8m0"] {
        eng.set_compress(spec).unwrap();
        let r = perplexity(&mut eng, &text, OPT).unwrap();
        incs.push(r.increase_pct(&base));
    }
    assert!(
        incs[0] <= incs[1] && incs[1] <= incs[2],
        "dtype ordering violated: {incs:?}"
    );
    assert!(incs[2] > incs[0], "fp3 should hurt more than fp5: {incs:?}");
}

#[test]
fn block_size_degradation_ordering_holds() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut eng = engine("nano", 2);
    let text = corpus("train");
    let base = perplexity(&mut eng, &text, OPT).unwrap();
    let mut incs = Vec::new();
    for block in [8, 16, 32] {
        eng.set_compress(&format!("fp4_e2m1_b{block}_e8m0")).unwrap();
        let r = perplexity(&mut eng, &text, OPT).unwrap();
        incs.push(r.increase_pct(&base));
    }
    // smaller blocks = finer scales = less damage (allow small noise)
    assert!(
        incs[0] <= incs[2] + 0.5,
        "block-size ordering violated: {incs:?}"
    );
}

#[test]
fn topk_is_catastrophic_like_table4() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut eng = engine("nano", 2);
    let text = corpus("test");
    let base = perplexity(&mut eng, &text, OPT).unwrap();
    eng.set_compress("topk3").unwrap();
    let topk = perplexity(&mut eng, &text, OPT).unwrap();
    eng.set_compress("fp4_e2m1_b32_e8m0").unwrap();
    let mx = perplexity(&mut eng, &text, OPT).unwrap();
    // Table 4: TopK degrades PPL by an order of magnitude more than MX4
    assert!(
        topk.increase_pct(&base) > 5.0 * mx.increase_pct(&base).max(0.1),
        "topk {} vs mx {}",
        topk.increase_pct(&base),
        mx.increase_pct(&base)
    );
}
