//! Bit-exactness cross-check: the rust MX codec vs the jnp reference,
//! over the golden vectors exported by `python -m compile.aot`
//! (artifacts/golden/codec). This is the contract that lets the
//! perplexity sweeps run through the rust codec while the Pallas
//! kernels carry the same math into the HLO artifacts.

use std::path::PathBuf;

use tpcc::mxfmt::{MxCodec, MxScheme};
use tpcc::util::json::Json;
use tpcc::util::npy::Npy;

fn golden_dir() -> Option<PathBuf> {
    let d = tpcc::artifacts_dir().join("golden/codec");
    d.join("index.json").exists().then_some(d)
}

#[test]
fn rust_codec_bitexact_vs_jnp_all_schemes() {
    let Some(dir) = golden_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let idx = Json::parse(&std::fs::read_to_string(dir.join("index.json")).unwrap()).unwrap();
    let schemes: Vec<String> = idx
        .get("schemes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.as_str().unwrap().to_string())
        .collect();
    assert!(schemes.len() >= 100, "expected the full scheme grid, got {}", schemes.len());

    let x = Npy::load(&dir.join("x.npy")).unwrap();
    let xs = x.as_f32().unwrap();

    let mut checked = 0usize;
    for name in &schemes {
        let scheme = MxScheme::parse(name).unwrap();
        let codec = MxCodec::new(scheme);
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        codec.quantize_unpacked(&xs, &mut codes, &mut scales);

        let g_codes = Npy::load(&dir.join(format!("{name}.codes.npy"))).unwrap();
        let g_scales = Npy::load(&dir.join(format!("{name}.scales.npy"))).unwrap();
        let g_deq = Npy::load(&dir.join(format!("{name}.deq.npy"))).unwrap();

        assert_eq!(codes, g_codes.as_u8().unwrap(), "codes mismatch for {name}");
        assert_eq!(scales, g_scales.as_u8().unwrap(), "scales mismatch for {name}");

        let mut deq = Vec::new();
        codec.dequantize_unpacked(&codes, &scales, &mut deq);
        let want = g_deq.as_f32().unwrap();
        assert_eq!(deq.len(), want.len());
        for (i, (a, b)) in deq.iter().zip(&want).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{name}: dequant mismatch at {i}: {a} vs {b}"
            );
        }
        checked += 1;
    }
    println!("verified {checked} schemes bit-exact");
}
