//! Integration tests for the span recorder + Chrome-trace export,
//! exercised through the crate's public API (including the HTTP
//! `GET /trace` endpoint via a detached coordinator handle). Runs
//! without AOT artifacts — these tests never touch the engine.

use std::sync::Arc;

use tpcc::coordinator::CoordinatorHandle;
use tpcc::obs::{self, Cat, Tracer};
use tpcc::server::{http_get, Server};
use tpcc::util::json::Json;

/// Count "X" (complete-span) events in a Chrome-trace document.
fn x_events(doc: &Json) -> Vec<&Json> {
    doc.get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array")
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .collect()
}

#[test]
fn cross_thread_spans_merge_into_one_sorted_timeline() {
    let tracer = Tracer::new();
    tracer.set_enabled(true);
    let joins: Vec<_> = (0..4u32)
        .map(|t| {
            let tracer = tracer.clone();
            std::thread::spawn(move || {
                obs::install(&tracer, &format!("worker{t}"), t);
                obs::set_pid(1);
                for _ in 0..8 {
                    let _g = obs::span("stage", Cat::Compute);
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }
    let dump = tracer.drain();
    assert_eq!(dump.spans.len(), 32);
    assert_eq!(dump.dropped, 0);
    // merged stream is sorted by start time
    for w in dump.spans.windows(2) {
        assert!(w[0].t0_ns <= w[1].t0_ns);
    }
    // every thread's spans survived the merge
    for t in 0..4u32 {
        assert_eq!(dump.spans.iter().filter(|s| s.tid == t).count(), 8, "tid {t}");
    }
    // recorder drained: a second drain is empty
    assert!(tracer.drain().spans.is_empty());
}

#[test]
fn export_is_valid_json_with_rank_thread_labels() {
    let tracer = Tracer::new();
    tracer.set_enabled(true);
    obs::install(&tracer, "test", 0);
    obs::set_pid(3);
    {
        let _outer = obs::span("prefill", Cat::Step);
        obs::set_tid(1);
        let _inner = obs::span_arg("attn", Cat::Compute, 0);
    }
    let body = tracer.drain().to_chrome_json().to_string();
    let doc = Json::parse(&body).expect("valid JSON");
    let xs = x_events(&doc);
    assert_eq!(xs.len(), 2);
    // per-rank thread labels land in the metadata events
    let names: Vec<&str> = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .unwrap()
        .iter()
        .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
        .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str()))
        .collect();
    assert!(names.contains(&"rank1"), "{names:?}");
}

#[test]
fn trace_endpoint_serves_snapshot_and_last_n() {
    let handle = CoordinatorHandle::detached();
    let tracer: Arc<Tracer> = handle.tracer.clone();
    tracer.set_enabled(true);
    obs::install(&tracer, "http-test", 0);
    obs::set_pid(7);
    {
        let _a = obs::span("older", Cat::Compute);
    }
    {
        let _b = obs::span("newer", Cat::Encode);
    }

    let server = Server::bind("127.0.0.1:0", handle).unwrap().with_pool(2, 8);
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.serve_n(3).unwrap());

    let (code, body) = http_get(&addr, "/trace").unwrap();
    assert_eq!(code, 200, "{body}");
    let doc = Json::parse(&body).expect("chrome-trace JSON");
    assert_eq!(x_events(&doc).len(), 2);

    // ?last=1 keeps only the newest span
    let (code, body) = http_get(&addr, "/trace?last=1").unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&body).unwrap();
    let xs = x_events(&doc);
    assert_eq!(xs.len(), 1);
    assert_eq!(xs[0].get("name").and_then(|n| n.as_str()), Some("newer"));

    // the endpoint snapshots (non-destructive): spans still present
    let (_, body) = http_get(&addr, "/trace").unwrap();
    assert_eq!(x_events(&Json::parse(&body).unwrap()).len(), 2);
    srv.join().unwrap();
}

#[test]
fn ring_overflow_keeps_newest_and_counts_dropped() {
    let tracer = Tracer::with_capacity(4);
    tracer.set_enabled(true);
    obs::install(&tracer, "overflow", 0);
    obs::set_pid(1);
    for _ in 0..10 {
        let _g = obs::span("s", Cat::Compute);
    }
    let dump = tracer.drain();
    assert_eq!(dump.spans.len(), 4);
    assert_eq!(dump.dropped, 6);
    assert!(tracer.dropped_total() >= 6);
}

#[test]
fn phase_gauges_mirror_guard_and_explicit_credit() {
    let tracer = Tracer::new();
    tracer.set_enabled(true);
    obs::install(&tracer, "phases", 2);
    {
        let _g = obs::span("embed", Cat::Compute);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    obs::add_virtual(Cat::Link, 0.25);
    obs::add_virtual(Cat::Fabric, 0.5);
    let m: std::collections::BTreeMap<String, f64> =
        tracer.phase_metrics().into_iter().collect();
    assert!(m["phase_compute_s"] > 0.0);
    assert_eq!(m["phase_codec_s"], 0.0);
    assert_eq!(m["phase_link_s"], 0.25);
    assert_eq!(m["phase_fabric_wait_s"], 0.5);
    assert_eq!(m["trace_spans_dropped"], 0.0);
}
