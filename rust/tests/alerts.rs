//! Injected-fault integration tests for the alert pipeline: synthetic
//! preemption storms and drift-sentinel trips must drive rules through
//! fire → resolve with the transitions observable on every surface at
//! once — `GET /alerts` JSON, `tpcc_alert_firing` Prometheus gauges,
//! and matching structured-log events on `GET /logs`. Also covers the
//! server's per-(route, status) request counters and build-info
//! exposure. Everything runs against a detached coordinator handle, so
//! no AOT artifacts are needed.

use std::io::{Read, Write};

use tpcc::coordinator::CoordinatorHandle;
use tpcc::metrics::history::Sample;
use tpcc::server::{http_get, Server};
use tpcc::util::json::Json;

fn boot(handle: CoordinatorHandle, requests: usize) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", handle).unwrap().with_pool(2, 8);
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.serve_n(requests).unwrap());
    (addr, srv)
}

fn rule_row<'a>(doc: &'a Json, name: &str) -> &'a Json {
    doc.get("rules")
        .and_then(|r| r.as_arr())
        .unwrap()
        .iter()
        .find(|r| r.get("name").and_then(|n| n.as_str()) == Some(name))
        .unwrap_or_else(|| panic!("rule {name} missing"))
}

fn count_events(logs: &Json, msg: &str, rule: &str) -> usize {
    logs.get("events")
        .and_then(|e| e.as_arr())
        .unwrap()
        .iter()
        .filter(|ev| {
            ev.get("msg").and_then(|m| m.as_str()) == Some(msg)
                && ev.get("rule").and_then(|r| r.as_str()) == Some(rule)
        })
        .count()
}

/// The acceptance path: two injected faults (a preemption storm from
/// synthetic history samples, a forced drift-sentinel trip) drive two
/// rules fire → resolve deterministically, with exactly one log event
/// per edge and the gauge flip visible over HTTP.
#[test]
fn injected_faults_fire_and_resolve_two_rules_over_http() {
    let handle = CoordinatorHandle::detached();
    let m = &handle.metrics;

    // storm: 16 preemptions over 3.5 s of synthetic samples (≫ 0.5/s)
    m.history.push(Sample { t_s: 0.0, ..Sample::default() });
    m.history.push(Sample { t_s: 1.0, preemptions: 5, ..Sample::default() });
    // drift: the sentinel's mirrored gauge reads 2 tripped sites
    m.set("drift_sites_tripped", 2.0);

    // tick 1: drift (for 0 s) fires immediately; the storm rule only
    // arms (for 2 s of hysteresis)
    handle.alerts.tick_at(m, &handle.log, 1.0);
    assert_eq!(handle.alerts.firing(), vec!["drift_tripped"]);

    m.history.push(Sample { t_s: 2.0, preemptions: 10, ..Sample::default() });
    handle.alerts.tick_at(m, &handle.log, 2.0); // held 1.0 s < 2 s: still pending
    assert_eq!(handle.alerts.firing(), vec!["drift_tripped"]);

    m.history.push(Sample { t_s: 3.5, preemptions: 16, ..Sample::default() });
    handle.alerts.tick_at(m, &handle.log, 3.5); // held 2.5 s ≥ 2 s: fires
    assert_eq!(handle.alerts.firing().len(), 2);

    let (addr, srv) = boot(handle.clone(), 6);

    // surface 1 while firing: /alerts JSON
    let (code, body) = http_get(&addr, "/alerts").unwrap();
    assert_eq!(code, 200, "{body}");
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("firing").and_then(|v| v.as_f64()), Some(2.0));
    let storm = rule_row(&doc, "preemption_storm");
    assert_eq!(storm.get("state").and_then(|s| s.as_str()), Some("firing"));
    assert!(storm.get("value").and_then(|v| v.as_f64()).unwrap() > 0.5, "{body}");
    assert_eq!(rule_row(&doc, "drift_tripped").get("state").and_then(|s| s.as_str()), Some("firing"));

    // surface 2 while firing: Prometheus gauges
    let (code, prom) = http_get(&addr, "/metrics?format=prom").unwrap();
    assert_eq!(code, 200);
    assert!(prom.contains("tpcc_alert_firing{rule=\"preemption_storm\"} 1\n"), "{prom}");
    assert!(prom.contains("tpcc_alert_firing{rule=\"drift_tripped\"} 1\n"), "{prom}");

    // clear both faults: a quiet sample far past the rate window ages
    // the storm out; the sentinel gauge drops back to zero
    handle.metrics.history.push(Sample { t_s: 20.0, preemptions: 16, ..Sample::default() });
    handle.metrics.set("drift_sites_tripped", 0.0);
    handle.alerts.tick_at(&handle.metrics, &handle.log, 20.0);
    assert!(handle.alerts.firing().is_empty());

    let (code, body) = http_get(&addr, "/alerts").unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("firing").and_then(|v| v.as_f64()), Some(0.0));
    for name in ["preemption_storm", "drift_tripped"] {
        let row = rule_row(&doc, name);
        assert_eq!(row.get("state").and_then(|s| s.as_str()), Some("inactive"), "{name}");
        assert_eq!(row.get("fired_total").and_then(|v| v.as_f64()), Some(1.0), "{name}");
        assert_eq!(row.get("resolved_total").and_then(|v| v.as_f64()), Some(1.0), "{name}");
    }

    let (_, prom) = http_get(&addr, "/metrics?format=prom").unwrap();
    assert!(prom.contains("tpcc_alert_firing{rule=\"preemption_storm\"} 0\n"), "{prom}");
    assert!(prom.contains("tpcc_alert_fired_total{rule=\"preemption_storm\"} 1\n"), "{prom}");
    assert!(prom.contains("tpcc_alert_resolved_total{rule=\"drift_tripped\"} 1\n"), "{prom}");

    // surface 3: the log carries exactly one event per edge. Firing
    // edges log at the rule's severity (warn), so the warn filter keeps
    // them; resolved edges log at info and need the full tail.
    let (code, warns) = http_get(&addr, "/logs?last=100&level=warn").unwrap();
    assert_eq!(code, 200);
    let warns = Json::parse(&warns).unwrap();
    assert_eq!(count_events(&warns, "alert firing", "preemption_storm"), 1, "{warns:?}");
    assert_eq!(count_events(&warns, "alert firing", "drift_tripped"), 1);
    assert_eq!(count_events(&warns, "alert resolved", "preemption_storm"), 0);

    let (_, all) = http_get(&addr, "/logs?last=100").unwrap();
    let all = Json::parse(&all).unwrap();
    assert_eq!(count_events(&all, "alert firing", "preemption_storm"), 1);
    assert_eq!(count_events(&all, "alert resolved", "preemption_storm"), 1);
    assert_eq!(count_events(&all, "alert resolved", "drift_tripped"), 1);
    srv.join().unwrap();
}

/// A cumulative-counter reset (coordinator restart feeding an old ring)
/// must read as a zero rate, not a negative or huge one — so no storm.
#[test]
fn counter_reset_reads_as_zero_rate_and_never_fires() {
    let handle = CoordinatorHandle::detached();
    let m = &handle.metrics;
    m.history.push(Sample { t_s: 0.0, preemptions: 100, ..Sample::default() });
    m.history.push(Sample { t_s: 1.0, preemptions: 2, ..Sample::default() });
    let rates = m.history.rates_at(10.0, 1.0).unwrap();
    assert_eq!(rates.preemptions_per_s, 0.0);
    handle.alerts.tick_at(m, &handle.log, 1.0);
    assert!(handle.alerts.firing().is_empty());
}

/// Every answered connection lands in the per-(route, status) counters:
/// known routes by literal, unknown paths as `(other)`, unparseable
/// requests as `(malformed)` — plus build info and uptime on both
/// metric surfaces, and access-log events for each request.
#[test]
fn http_request_counters_build_info_and_access_log_over_http() {
    let handle = CoordinatorHandle::detached();
    let (addr, srv) = boot(handle, 6);

    let (code, _) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    let (code, _) = http_get(&addr, "/no/such/route").unwrap();
    assert_eq!(code, 404);

    // a malformed request line (no path) must answer 400 and count
    let mut raw = std::net::TcpStream::connect(&addr).unwrap();
    raw.write_all(b"GARBAGE\r\n\r\n").unwrap();
    let mut resp = String::new();
    raw.read_to_string(&mut resp).unwrap();
    assert!(resp.contains("400"), "{resp}");
    drop(raw);

    // the recorder runs right after each response is written; give the
    // worker that instant before reading the counters back
    std::thread::sleep(std::time::Duration::from_millis(50));

    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&body).unwrap();
    let http = doc.get("http_requests").expect("http_requests object");
    let count = |route: &str, status: &str| {
        http.get(route).and_then(|r| r.get(status)).and_then(|v| v.as_f64())
    };
    assert_eq!(count("/healthz", "200"), Some(1.0), "{body}");
    assert_eq!(count("(other)", "404"), Some(1.0), "{body}");
    assert_eq!(count("(malformed)", "400"), Some(1.0), "{body}");
    assert!(doc.get("build_version").and_then(|v| v.as_str()).is_some_and(|v| !v.is_empty()));
    assert!(doc.get("build_git").and_then(|v| v.as_str()).is_some_and(|v| !v.is_empty()));
    assert!(doc.get("uptime_seconds").and_then(|v| v.as_f64()).unwrap() >= 0.0);

    let (_, prom) = http_get(&addr, "/metrics?format=prom").unwrap();
    assert!(prom.contains("tpcc_http_requests_total{path=\"/healthz\",status=\"200\"} 1\n"), "{prom}");
    assert!(prom.contains("tpcc_http_requests_total{path=\"(malformed)\",status=\"400\"} 1\n"), "{prom}");
    assert!(prom.contains("tpcc_build_info{version=\""), "{prom}");
    assert!(prom.contains("tpcc_uptime_seconds "), "{prom}");
    assert!(prom.contains("tpcc_alert_firing{rule=\"ttft_slo_burn\"} 0\n"), "{prom}");

    // one access-log event per answered request, raw path preserved
    let (code, logs) = http_get(&addr, "/logs?last=100").unwrap();
    assert_eq!(code, 200);
    let logs = Json::parse(&logs).unwrap();
    let access: Vec<&Json> = logs
        .get("events")
        .and_then(|e| e.as_arr())
        .unwrap()
        .iter()
        .filter(|ev| ev.get("msg").and_then(|m| m.as_str()) == Some("access"))
        .collect();
    assert!(access.len() >= 4, "access events: {}", access.len());
    assert!(access
        .iter()
        .any(|ev| ev.get("path").and_then(|p| p.as_str()) == Some("/no/such/route")));
    assert!(access
        .iter()
        .all(|ev| ev.get("latency_s").and_then(|l| l.as_f64()).unwrap() >= 0.0));
    srv.join().unwrap();
}
