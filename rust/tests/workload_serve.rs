//! Serving-under-load integration: the workload engine driving the
//! real coordinator (live nano engine, artifacts-gated) and the
//! virtual-time driver over the modeled paper-scale engine (ungated).

use tpcc::coordinator::{spawn, CoordinatorOptions};
use tpcc::interconnect::HwProfile;
use tpcc::model::perf_model::LLAMA2_13B;
use tpcc::model::weights::Weights;
use tpcc::policy::PolicyTable;
use tpcc::runtime::Runtime;
use tpcc::tp::{EngineOptions, TpEngine};
use tpcc::workload::{
    drive, simulate, Arrival, DriveOptions, LenDist, ModeledEngine, SimOptions, TraceSpec,
};

fn have_artifacts() -> bool {
    tpcc::artifacts_dir().join("manifest.json").exists()
}

/// Ungated: a bursty trace through the virtual-time driver against the
/// modeled 13B/4xL4 engine — every request completes, percentiles are
/// finite, queueing is visible.
#[test]
fn simulated_bursty_load_end_to_end() {
    let profile = HwProfile::by_name("l4").unwrap();
    let table = PolicyTable::uniform(LLAMA2_13B.n_layers, "fp4_e2m1_b32_e8m0");
    let mut eng = ModeledEngine::new(LLAMA2_13B, profile, 4, &table).unwrap();
    let trace = TraceSpec {
        arrival: Arrival::Bursty { rate: 6.0, cv: 3.0 },
        prompt_len: LenDist::LogNormal { median: 48.0, sigma: 1.0, cap: 224 },
        output_len: LenDist::LogNormal { median: 16.0, sigma: 0.7, cap: 64 },
        requests: 150,
        seed: 23,
    }
    .generate();
    let r = simulate(&trace, &mut eng, &SimOptions::default());
    assert_eq!(r.completed, 150, "all requests must complete ({} failed)", r.failed);
    assert_eq!(r.failed, 0);
    for (name, h) in
        [("ttft", &r.ttft), ("e2e", &r.e2e), ("queue_wait", &r.queue_wait)]
    {
        assert!(h.count() > 0, "{name} never recorded");
        for p in [50.0, 95.0, 99.0] {
            let v = h.percentile(p);
            assert!(v.is_finite() && v >= 0.0, "{name} p{p} = {v}");
        }
    }
    // invariants: e2e dominates ttft dominates queue wait (medians)
    assert!(r.e2e.percentile(50.0) >= r.ttft.percentile(50.0));
    assert!(r.ttft.percentile(50.0) > r.queue_wait.percentile(50.0));
    assert!((0.0..=1.0).contains(&r.goodput()));
    assert!(r.makespan_s >= trace.span_s());
    assert!(r.tokens_out > 150, "decode produced tokens");
}

/// Ungated: the same simulated load publishes valid, finite workload
/// metrics into a registry (what `tpcc load` serves on /metrics).
#[test]
fn simulated_report_publishes_metrics() {
    let profile = HwProfile::by_name("l4").unwrap();
    let table = PolicyTable::uniform(LLAMA2_13B.n_layers, "none");
    let mut eng = ModeledEngine::new(LLAMA2_13B, profile, 4, &table).unwrap();
    let trace = TraceSpec {
        arrival: Arrival::Poisson { rate: 4.0 },
        prompt_len: LenDist::Fixed(64),
        output_len: LenDist::Fixed(8),
        requests: 60,
        seed: 5,
    }
    .generate();
    let r = simulate(&trace, &mut eng, &SimOptions::default());
    let reg = tpcc::metrics::Registry::default();
    r.publish(&reg);
    let body = reg.to_json().to_string();
    let j = tpcc::util::json::Json::parse(&body).expect("metrics must stay valid JSON");
    assert_eq!(j.get("workload_completed").unwrap().as_i64(), Some(60));
    assert!(j.get("workload_ttft_p50_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("workload_ttft_p99_s").is_some());
    let goodput = j.get("workload_goodput").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&goodput));
}

/// Artifacts-gated: a bursty trace end-to-end through the real
/// coordinator + nano engine. All requests complete, percentiles are
/// finite, and the coordinator's queue-wait histogram fills.
#[test]
fn live_bursty_trace_end_to_end() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn(
        move || {
            let root = tpcc::artifacts_dir();
            let rt = Runtime::load(&root)?;
            let weights = Weights::load(&root.join("weights/nano"))?;
            TpEngine::new(rt, &weights, EngineOptions::new("nano", 2).with_compress("fp4_e2m1_b32_e8m0"))
        },
        CoordinatorOptions::default(),
    )
    .unwrap();
    // fast bursty arrivals so the test stays quick but still queues
    let trace = TraceSpec {
        arrival: Arrival::Bursty { rate: 40.0, cv: 3.0 },
        prompt_len: LenDist::Uniform { lo: 8, hi: 48 },
        output_len: LenDist::Fixed(6),
        requests: 10,
        seed: 77,
    }
    .generate();
    let report = drive(&handle, &trace, &DriveOptions { slo_ttft_s: 30.0 });
    assert_eq!(report.completed, 10, "{} failed", report.failed);
    assert_eq!(report.failed, 0);
    assert!(report.ttft.percentile(50.0).is_finite());
    assert!(report.e2e.percentile(95.0).is_finite());
    assert!(report.tpot.percentile(50.0).is_finite());
    assert!(report.queue_wait.count() > 0, "queue wait never recorded");
    // a 30s TTFT SLO on a 10-request nano run is always met
    assert!((report.goodput() - 1.0).abs() < 1e-9, "goodput {}", report.goodput());
    // the coordinator recorded queue waits into its own registry too
    assert_eq!(handle.metrics.queue_wait.count(), 10);
    let m = handle.metrics.to_json();
    assert!(m.get("queue_wait_p50_s").unwrap().as_f64().is_some());
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}

/// Artifacts-gated: closed-loop driving keeps the pipeline full and
/// completes everything.
#[test]
fn live_closed_loop_completes() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn(
        move || {
            let root = tpcc::artifacts_dir();
            let rt = Runtime::load(&root)?;
            let weights = Weights::load(&root.join("weights/nano"))?;
            TpEngine::new(rt, &weights, EngineOptions::new("nano", 2))
        },
        CoordinatorOptions::default(),
    )
    .unwrap();
    let trace = TraceSpec {
        arrival: Arrival::Closed { concurrency: 4, think_s: 0.0 },
        prompt_len: LenDist::Fixed(16),
        output_len: LenDist::Fixed(4),
        requests: 8,
        seed: 3,
    }
    .generate();
    let report = drive(&handle, &trace, &DriveOptions::default());
    assert_eq!(report.completed, 8);
    assert!(report.tokens_out >= 8 * 4);
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}
