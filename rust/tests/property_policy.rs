//! Property tests for the per-site compression policy engine:
//! the `uniform` policy must be **bit-identical** to the seed's global
//! single-compressor path across world sizes, policy specs must
//! round-trip through their serialisations, and the built-in searches
//! must honour their structural guarantees. No artifacts needed except
//! for the final engine-level test (skipped when absent, like the
//! other engine integration tests).

use tpcc::collective::plan::{self, AlgoChoice};
use tpcc::collective::{execute, CommScratch, Topology};
use tpcc::interconnect::{HwProfile, LinkModel};
use tpcc::mxfmt::{compressor_from_spec_ch, Compressor};
use tpcc::policy::{
    auto_search, paper_policy, Calibration, CompressionPolicy, Phase, PolicyTable, SearchScenario,
    Site, SiteCosts, SiteKind, CANDIDATES,
};
use tpcc::util::rng::Rng;

const D_MODEL: usize = 192; // micro's hidden dim: multiple of 32, channel-wise friendly

fn link() -> LinkModel {
    LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9 }
}

/// The seed path (one global compressor) vs the policy path (the
/// compressor resolved per-site from a `uniform:<spec>` table) must
/// produce bit-identical reduced outputs — for every world size, every
/// site, and both a block-wise and a channel-wise scheme.
#[test]
fn prop_uniform_policy_bit_identical_to_seed_path() {
    let n_layers = 3;
    let profile = HwProfile::by_name("l4").unwrap();
    let mut rng = Rng::new(21);
    for spec in ["fp4_e2m1_b32_e8m0", "fp5_e2m2_b16_e8m0", "int4_channelwise"] {
        let policy = CompressionPolicy::parse(&format!("uniform:{spec}")).unwrap();
        let table = policy.table(n_layers);
        // the table resolves every site to the engine-wide spec ...
        for site in Site::all(n_layers) {
            assert_eq!(table.spec(site), spec, "{}", site.label());
        }
        for world in [1usize, 2, 3, 4, 8] {
            let topo = Topology::from_profile(profile, world);
            for len in [D_MODEL, 5 * D_MODEL, 16 * D_MODEL] {
                let mut x = vec![0.0f32; len];
                rng.fill_activations(&mut x, 1.0);
                let mut parts = vec![vec![0.0f32; len]; world];
                for p in &mut parts {
                    rng.fill_activations(p, 2.0);
                }

                // seed path: one engine-wide compressor
                let seed_comp = compressor_from_spec_ch(spec, D_MODEL).unwrap();
                let seed_plan = plan::choose(
                    len,
                    world,
                    Some(seed_comp.as_ref()),
                    &topo,
                    profile.quant_values_per_s,
                    AlgoChoice::Auto,
                );
                let mut seed_out = Vec::new();
                let mut scratch = CommScratch::default();
                let seed_rep = execute(
                    &seed_plan,
                    &x,
                    &parts,
                    Some(seed_comp.as_ref()),
                    &topo,
                    true,
                    &mut seed_out,
                    &mut scratch,
                );

                // ... and the per-site-resolved compressor reproduces the
                // seed path bit-for-bit (identical plan, output, bytes)
                let site = Site::all(n_layers)[0];
                let comp = compressor_from_spec_ch(table.spec(site), D_MODEL).unwrap();
                let p = plan::choose(
                    len,
                    world,
                    Some(comp.as_ref()),
                    &topo,
                    profile.quant_values_per_s,
                    AlgoChoice::Auto,
                );
                assert_eq!(p, seed_plan, "{spec}/w{world}/{len}: plans differ");
                let mut out = Vec::new();
                let rep =
                    execute(&p, &x, &parts, Some(comp.as_ref()), &topo, true, &mut out, &mut scratch);
                assert_eq!(
                    out, seed_out,
                    "{spec}/w{world}/{len}: outputs not bit-identical"
                );
                assert_eq!(rep.wire_bytes, seed_rep.wire_bytes);
                assert_eq!(rep.raw_bytes, seed_rep.raw_bytes);
            }
        }
    }
}

/// `uniform:none` resolves every site to the uncompressed path.
#[test]
fn prop_uniform_none_resolves_to_uncompressed_everywhere() {
    let table = CompressionPolicy::parse("uniform:none").unwrap().table(5);
    assert_eq!(table.is_uniform(), Some("none"));
    for site in Site::all(5) {
        assert_eq!(table.spec(site), "none");
    }
}

/// Spec-string round trip: parse → serialize → parse resolves every
/// site identically, for rule policies of increasing complexity.
#[test]
fn prop_policy_spec_roundtrip() {
    let specs = [
        "uniform:none",
        "uniform:fp4_e2m1_b32_e8m0",
        "mlp=fp4_e2m1_b32_e8m0",
        "mlp=fp4_e2m1_b32_e8m0;attn=none;layers[0,3]=none;decode=none",
        "default=fp5_e2m2_b32_e8m0;layers[1-2].mlp=int4_channelwise;layers[0].attn.decode=none",
    ];
    for s in specs {
        let p = CompressionPolicy::parse(s).unwrap();
        let p2 = CompressionPolicy::parse(&p.to_spec_string()).unwrap();
        for n_layers in [1usize, 4, 8] {
            let (a, b) = (p.table(n_layers), p2.table(n_layers));
            for site in Site::all(n_layers) {
                assert_eq!(a.spec(site), b.spec(site), "{s} @ {}", site.label());
            }
        }
    }
}

/// JSON serialisation covers every site with its resolved scheme.
#[test]
fn prop_policy_json_covers_all_sites() {
    let p = CompressionPolicy::parse("mlp=fp4_e2m1_b32_e8m0;decode=none").unwrap();
    let table = p.table(3);
    let j = table.to_json();
    let sites = j.get("sites").unwrap().as_obj().unwrap();
    assert_eq!(sites.len(), Site::count(3));
    for site in Site::all(3) {
        assert_eq!(
            sites.get(&site.label()).and_then(|v| v.as_str()),
            Some(table.spec(site)),
            "{}",
            site.label()
        );
    }
}

/// The auto search's structural guarantee, across TP degrees: never
/// slower than the uniform baseline (total and TTFT-phase virtual
/// time) at equal-or-better modeled error.
#[test]
fn prop_auto_never_worse_than_uniform_across_worlds() {
    let n_layers = 2;
    let profile = HwProfile::by_name("2x4l4").unwrap();
    for world in [2usize, 4, 8] {
        let calib = Calibration::synthetic(n_layers, D_MODEL, world, 17);
        let scen = SearchScenario::new(profile, world, 8 * 128, 8, D_MODEL);
        let costs = SiteCosts::build(&calib, &scen, CANDIDATES).unwrap();
        let uniform = PolicyTable::uniform(n_layers, "fp4_e2m1_b32_e8m0");
        let u = costs.eval_table(&uniform).unwrap();
        let auto = auto_search(&costs, n_layers, u.mean_err_pct(), Some(&uniform), "auto").unwrap();
        assert!(auto.score.time_total_s <= u.time_total_s + 1e-12, "world {world}");
        assert!(auto.score.ttft_comm_s <= u.ttft_comm_s + 1e-12, "world {world}");
        assert!(auto.score.mean_err_pct() <= u.mean_err_pct() + 1e-9, "world {world}");
    }
}

/// The paper policy only ever assigns candidate schemes, and its
/// threshold extremes pin the two degenerate tables.
#[test]
fn prop_paper_policy_assigns_candidates_only() {
    let calib = Calibration::synthetic(4, D_MODEL, 2, 9);
    let t = paper_policy(&calib, 3.0).unwrap();
    for site in Site::all(4) {
        let spec = t.spec(site);
        assert!(
            CANDIDATES.contains(&spec),
            "{}: {spec} not a candidate",
            site.label()
        );
        // §5.1 searches the MX grid only — channel-wise INT never appears
        assert_ne!(spec, "int4_channelwise");
    }
    let t0 = paper_policy(&calib, 0.0).unwrap();
    for site in Site::all(4) {
        assert_eq!(t0.spec(site), "none");
    }
}

/// Calibration error agrees between the trait object path and the
/// spec-string path, and responds to the compressor's fidelity:
/// a strictly finer scheme family member never reports NaN/negative.
#[test]
fn prop_calibration_error_consistency() {
    let calib = Calibration::synthetic(2, D_MODEL, 3, 23);
    for site in Site::all(2) {
        for spec in ["fp4_e2m1_b32_e8m0", "fp5_e2m2_b8_e8m0", "int4_channelwise"] {
            let via_spec = calib.scheme_error(site, spec).unwrap();
            let comp: Box<dyn Compressor> = compressor_from_spec_ch(spec, D_MODEL).unwrap();
            let via_comp = calib.site_error(site, Some(comp.as_ref()));
            assert_eq!(via_spec, via_comp, "{spec} @ {}", site.label());
            assert!(via_spec.is_finite() && via_spec >= 0.0);
        }
    }
}

/// Engine-level pin (needs artifacts, like the other engine tests):
/// an engine built with `--compress <spec>` and one built with
/// `--policy uniform:<spec>` must produce identical logits.
#[test]
fn engine_uniform_policy_matches_global_compressor() {
    let root = tpcc::artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    use tpcc::model::weights::Weights;
    use tpcc::runtime::Runtime;
    use tpcc::tp::{EngineOptions, TpEngine};

    let spec = "fp4_e2m1_b32_e8m0";
    let prompt: Vec<i32> = (0..128).map(|i| (i * 17 + 3) % 256).collect();
    let mut outs = Vec::new();
    for policy in ["", "uniform:fp4_e2m1_b32_e8m0"] {
        let rt = Runtime::load(&root).unwrap();
        let weights = Weights::load(&root.join("weights/nano")).unwrap();
        let opts = EngineOptions::new("nano", 2).with_compress(spec).with_policy(policy);
        let mut eng = TpEngine::new(rt, &weights, opts).unwrap();
        assert_eq!(eng.policy().is_uniform(), Some(spec));
        let (logits, t) = eng.prefill(&prompt, 1, 128, &[0], None).unwrap();
        // per-site stats cover exactly the prefill sites that ran
        let calls: u64 = eng.site_stats().iter().map(|s| s.calls).sum();
        assert_eq!(calls, 2 * eng.cfg.n_layers as u64);
        assert!(t.wire_bytes > 0);
        outs.push((logits, t.wire_bytes));
    }
    assert_eq!(outs[0].1, outs[1].1, "wire accounting differs");
    assert_eq!(outs[0].0, outs[1].0, "uniform policy logits differ from seed path");
}

/// A mixed policy on a live engine (needs artifacts): `attn=none`
/// leaves attention collectives uncompressed — their wire bytes must
/// account at the fp16 baseline while MLP sites compress.
#[test]
fn engine_mixed_policy_site_accounting() {
    let root = tpcc::artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    use tpcc::model::weights::Weights;
    use tpcc::runtime::Runtime;
    use tpcc::tp::{EngineOptions, TpEngine};

    let rt = Runtime::load(&root).unwrap();
    let weights = Weights::load(&root.join("weights/nano")).unwrap();
    let opts = EngineOptions::new("nano", 2)
        .with_compress("fp4_e2m1_b32_e8m0")
        .with_policy("attn=none");
    let mut eng = TpEngine::new(rt, &weights, opts).unwrap();
    assert!(eng.policy().is_uniform().is_none());
    let prompt: Vec<i32> = (0..128).map(|i| (i * 7 + 1) % 256).collect();
    let _ = eng.prefill(&prompt, 1, 128, &[0], None).unwrap();
    for site in Site::all(eng.cfg.n_layers) {
        if site.phase != Phase::Prefill {
            continue;
        }
        let st = &eng.site_stats()[site.index()];
        assert_eq!(st.calls, 1, "{}", site.label());
        match site.kind {
            SiteKind::AttnOut => {
                assert_eq!(st.wire_bytes, st.raw_bytes, "{}", site.label())
            }
            SiteKind::MlpOut => {
                assert!(st.wire_bytes < st.raw_bytes / 3, "{}", site.label())
            }
        }
    }
    // the policy metric rollups agree with the per-site stats
    let metrics = eng.policy_metrics();
    let attn_wire = metrics
        .iter()
        .find(|(k, _)| k == "policy_wire_bytes_attn_prefill")
        .map(|(_, v)| *v)
        .unwrap();
    let expect: u64 = Site::all(eng.cfg.n_layers)
        .into_iter()
        .filter(|s| s.kind == SiteKind::AttnOut && s.phase == Phase::Prefill)
        .map(|s| eng.site_stats()[s.index()].wire_bytes)
        .sum();
    assert_eq!(attn_wire as u64, expect);
}

/// The collective link used by the pure-collective tests above stays
/// exercised (keeps this file self-contained if profiles change).
#[test]
fn sanity_flat_link_collective_unchanged() {
    let x = vec![1.0f32; 64];
    let parts = vec![vec![0.5f32; 64], vec![0.25f32; 64]];
    let (mut out, mut wire) = (Vec::new(), Vec::new());
    let rep =
        tpcc::collective::all_gather_reduce_add(&x, &parts, None, &link(), &mut out, &mut wire);
    assert!(out.iter().all(|&v| (v - 1.75).abs() < 1e-7));
    assert_eq!(rep.algo, "ring");
}
