//! Integration tests for the telemetry subsystem's HTTP surface —
//! Prometheus text exposition, the `/metrics/history` time-series, and
//! the `/debug/requests` flight-recorder dump — exercised through a
//! detached coordinator handle so they run without AOT artifacts.

use tpcc::coordinator::CoordinatorHandle;
use tpcc::obs::flight::{self, RequestRecord};
use tpcc::server::{http_get, Server};
use tpcc::util::json::Json;

fn boot(handle: CoordinatorHandle, requests: usize) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind("127.0.0.1:0", handle).unwrap().with_pool(2, 8);
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.serve_n(requests).unwrap());
    (addr, srv)
}

/// Minimal Prometheus text-format lint: every non-comment, non-blank
/// line is `name[{labels}] value` with a finite numeric value and a
/// name in the legal charset.
fn lint_prometheus(body: &str) -> usize {
    let mut samples = 0;
    for line in body.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("prometheus sample line has no value: {line:?}");
        });
        let name = name_part.split('{').next().unwrap();
        assert!(
            !name.is_empty()
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name in {line:?}"
        );
        let v: f64 = value.parse().unwrap_or_else(|_| {
            panic!("unparseable sample value in {line:?}");
        });
        assert!(v.is_finite(), "non-finite sample in {line:?}");
        samples += 1;
    }
    samples
}

#[test]
fn metrics_endpoint_serves_lintable_prometheus_text() {
    let handle = CoordinatorHandle::detached();
    handle.metrics.requests_received.add(3);
    handle.metrics.requests_completed.add(2);
    handle.metrics.tokens_generated.add(40);
    handle.metrics.comm_bytes_sent.add(1 << 20);
    handle.metrics.ttft.record(0.12);
    handle.metrics.set("drift_sites_tripped", 0.0);

    let (addr, srv) = boot(handle, 3);

    // prom format behind the query knob (both spellings)
    let (code, body) = http_get(&addr, "/metrics?format=prom").unwrap();
    assert_eq!(code, 200, "{body}");
    assert!(body.contains("# TYPE tpcc_requests_completed counter"), "{body}");
    assert!(body.contains("# TYPE tpcc_kv_blocks_in_use gauge"), "{body}");
    assert!(body.contains("tpcc_ttft_seconds_count 1"), "{body}");
    assert!(body.contains("tpcc_drift_sites_tripped"), "{body}");
    assert!(lint_prometheus(&body) >= 10, "suspiciously few samples:\n{body}");

    let (code, prom2) = http_get(&addr, "/metrics?format=prometheus").unwrap();
    assert_eq!(code, 200);
    assert!(prom2.contains("tpcc_requests_received 3"), "{prom2}");

    // the default /metrics stays JSON
    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let doc = Json::parse(&body).expect("JSON metrics");
    assert_eq!(doc.get("requests_completed").and_then(|v| v.as_f64()), Some(2.0));
    srv.join().unwrap();
}

#[test]
fn metrics_history_endpoint_reports_windowed_rates() {
    let handle = CoordinatorHandle::detached();
    handle.metrics.sample_history();
    handle.metrics.requests_completed.add(5);
    handle.metrics.tokens_generated.add(100);
    handle.metrics.comm_bytes_sent.add(10 << 20);
    std::thread::sleep(std::time::Duration::from_millis(5));
    handle.metrics.sample_history();

    let (addr, srv) = boot(handle, 1);
    let (code, body) = http_get(&addr, "/metrics/history").unwrap();
    assert_eq!(code, 200, "{body}");
    let doc = Json::parse(&body).expect("history JSON");
    assert!(doc.get("samples").and_then(|v| v.as_f64()).unwrap() >= 2.0);
    assert!(doc.get("span_s").and_then(|v| v.as_f64()).unwrap() > 0.0);

    let rates = doc.get("rates").and_then(|r| r.as_arr()).expect("rates array");
    assert_eq!(rates.len(), 4);
    // the 10 s window holds both samples, so the counter delta shows up
    // as a positive rate (the window clamps to the actual tiny span)
    let short = &rates[0];
    assert_eq!(short.get("requested_window_s").and_then(|v| v.as_f64()), Some(10.0));
    assert!(short.get("qps").and_then(|v| v.as_f64()).unwrap() > 0.0, "{body}");
    assert!(short.get("tokens_per_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert!(short.get("wire_gb_per_s").and_then(|v| v.as_f64()).unwrap() > 0.0);

    let burn = doc.get("burn").and_then(|b| b.as_arr()).expect("burn array");
    assert_eq!(burn.len(), 3);
    srv.join().unwrap();
}

#[test]
fn debug_requests_endpoint_round_trips_flight_records() {
    let handle = CoordinatorHandle::detached();
    for i in 0..3u64 {
        let mut r = RequestRecord {
            id: i,
            prompt_tokens: 64,
            new_tokens: 8,
            batch_peak: 2,
            ttft_s: 0.05,
            e2e_s: 0.1 + 0.2 * i as f64,
            ..RequestRecord::default()
        };
        r.decode.compute_s = 0.02 * (i + 1) as f64;
        r.site_wire_bytes = [1000, 2000, 3000, 4000];
        handle.flight.record(r);
    }

    let (addr, srv) = boot(handle, 1);
    let (code, body) = http_get(&addr, "/debug/requests").unwrap();
    assert_eq!(code, 200, "{body}");
    let doc = Json::parse(&body).expect("flight JSON");
    assert_eq!(doc.get("total").and_then(|v| v.as_f64()), Some(3.0));
    assert_eq!(doc.get("site_groups").and_then(|g| g.as_arr()).unwrap().len(), 4);
    assert_eq!(doc.get("recent").and_then(|g| g.as_arr()).unwrap().len(), 3);
    assert!(!doc.get("slowest").and_then(|g| g.as_arr()).unwrap().is_empty());

    // the dump is exactly what `tpcc explain --addr` consumes
    let records = flight::records_from_json(&doc);
    assert_eq!(records.len(), 3);
    let a = flight::attribution(&records).expect("attribution over 3 records");
    let table = flight::render_attribution(&a);
    assert!(table.contains("tail attribution over 3 requests"), "{table}");
    assert!(table.contains("decode.compute"), "{table}");
    assert!(table.contains("site group"), "{table}");
    srv.join().unwrap();
}
