//! Property-based tests (hand-rolled generator — no proptest in the
//! offline vendor set) over the codec + collective invariants the
//! coordinator relies on.

use tpcc::collective::all_gather_reduce_add;
use tpcc::interconnect::LinkModel;
use tpcc::mxfmt::{Compressor, ElemFormat, MxCodec, MxScheme, ELEM_FORMATS};
use tpcc::util::rng::Rng;

fn schemes(rng: &mut Rng) -> MxScheme {
    let elem: &ElemFormat = &ELEM_FORMATS[rng.below(ELEM_FORMATS.len())];
    let block = [8usize, 16, 32][rng.below(3)];
    let sbits = [4u32, 5, 6, 7, 8][rng.below(5)];
    MxScheme::new(elem.name, block, sbits).unwrap()
}

fn data(rng: &mut Rng, n: usize, spread: f32) -> Vec<f32> {
    let mut x = vec![0.0f32; n];
    rng.fill_activations(&mut x, spread);
    // salt edge cases
    if n >= 4 {
        x[0] = 0.0;
        x[1] = -0.0;
        let i = 2 + rng.below(n - 2);
        x[i] = if rng.f32() < 0.5 { 3.0e38 } else { 1.0e-38 };
    }
    x
}

/// Quantization must be *idempotent*: re-quantizing its own output
/// changes nothing (the output lies on the representable grid).
#[test]
fn prop_quantize_idempotent() {
    let mut rng = Rng::new(101);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(16));
        let spread = rng.range_f32(0.5, 6.0);
        let mut x = data(&mut rng, n, spread);
        c.fake_quantize(&mut x);
        let once = x.clone();
        c.fake_quantize(&mut x);
        assert_eq!(once, x, "case {case} scheme {}", s.name());
    }
}

/// decode(encode(x)) == fake_quantize(x) for every scheme: the wire
/// path and the in-place error-injection path are the same function.
#[test]
fn prop_wire_equals_fake_quantize() {
    let mut rng = Rng::new(202);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(16));
        let spread = rng.range_f32(0.5, 6.0);
        let x = data(&mut rng, n, spread);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        // wire layout: bit-packed codes + one scale byte per block
        let expect = (n * s.elem.bits() as usize).div_ceil(8) + n / s.block;
        assert_eq!(wire.len(), expect, "case {case}");
        let decoded = c.decode(&wire, n);
        let mut fq = x.clone();
        c.fake_quantize(&mut fq);
        assert_eq!(decoded, fq, "case {case} scheme {}", s.name());
    }
}

/// Dequantized outputs never exceed the block's representable maximum
/// and are always finite.
#[test]
fn prop_outputs_bounded_finite() {
    let mut rng = Rng::new(303);
    for _ in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(8));
        let mut x = data(&mut rng, n, 8.0);
        c.fake_quantize(&mut x);
        for v in &x {
            assert!(v.is_finite());
        }
    }
}

/// Sign symmetry: quantize(-x) == -quantize(x).
#[test]
fn prop_sign_symmetry() {
    let mut rng = Rng::new(404);
    for _ in 0..40 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(8));
        let x = data(&mut rng, n, 3.0);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let mut a = x.clone();
        let mut b = neg.clone();
        c.fake_quantize(&mut a);
        c.fake_quantize(&mut b);
        for (p, q) in a.iter().zip(&b) {
            assert_eq!(*p, -*q);
        }
    }
}

/// More effective bits never hurt (on average): for the same block
/// size, fp5 MSE <= fp4 MSE <= fp3 MSE on random activation data.
#[test]
fn prop_bits_monotone_mse() {
    let mut rng = Rng::new(505);
    for _ in 0..10 {
        let n = 32 * 64;
        let x = data(&mut rng, n, 3.0);
        let mut prev = 0.0f64;
        for elem in ["fp5_e2m2", "fp4_e2m1", "fp3_e1m1"] {
            let c = MxCodec::new(MxScheme::new(elem, 32, 8).unwrap());
            let mut y = x.clone();
            c.fake_quantize(&mut y);
            let mse: f64 = x
                .iter()
                .zip(&y)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
            // error grows as precision shrinks: fp5 <= fp4 <= fp3
            assert!(mse * 1.001 >= prev, "{elem}: {mse} < {prev}");
            prev = mse;
        }
    }
}

/// Collective linearity: reduce(x, parts) - x == sum of decode(parts)
/// regardless of worker count, and the uncompressed path is exact.
#[test]
fn prop_collective_linear_uncompressed() {
    let mut rng = Rng::new(606);
    let link = LinkModel { alpha_s: 0.0, beta_bytes_per_s: 1e9 };
    for _ in 0..20 {
        let n = 32 * (1 + rng.below(8));
        let tp = [1usize, 2, 4, 8][rng.below(4)];
        let x = data(&mut rng, n, 1.0);
        let parts: Vec<Vec<f32>> = (0..tp).map(|_| data(&mut rng, n, 1.0)).collect();
        let mut out = Vec::new();
        let mut wire = Vec::new();
        all_gather_reduce_add(&x, &parts, None, &link, &mut out, &mut wire);
        for i in 0..n {
            let want: f32 = x[i] + parts.iter().map(|p| p[i]).sum::<f32>();
            assert!((out[i] - want).abs() <= 1e-4 * want.abs().max(1.0));
        }
    }
}

/// Wire size accounting: the packed wire is exactly the analytic size
/// and strictly smaller than fp16 for every MX scheme.
#[test]
fn prop_wire_size_exact() {
    let mut rng = Rng::new(707);
    for _ in 0..40 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(32));
        let x = data(&mut rng, n, 2.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let nblocks = n / s.block;
        let expect = (n * s.elem.bits() as usize).div_ceil(8) + nblocks;
        assert_eq!(wire.len(), expect, "{}", s.name());
        assert!(c.wire_bytes(n) <= n * 2, "never larger than fp16: {}", s.name());
        // analytic effective bits match the scheme definition
        assert!((c.effective_bits(n) - s.effective_bits()).abs() < 1e-12);
    }
}
