//! Property-based tests (hand-rolled generator — no proptest in the
//! offline vendor set) over the codec + collective invariants the
//! coordinator relies on.

use tpcc::collective::all_gather_reduce_add;
use tpcc::interconnect::LinkModel;
use tpcc::mxfmt::{fuzz, Compressor, ElemFormat, MxCodec, MxScheme, RefMxCodec, ELEM_FORMATS};
use tpcc::util::json::Json;
use tpcc::util::rng::Rng;

fn schemes(rng: &mut Rng) -> MxScheme {
    let elem: &ElemFormat = &ELEM_FORMATS[rng.below(ELEM_FORMATS.len())];
    let block = [8usize, 16, 32][rng.below(3)];
    let sbits = [4u32, 5, 6, 7, 8][rng.below(5)];
    MxScheme::new(elem.name, block, sbits).unwrap()
}

fn data(rng: &mut Rng, n: usize, spread: f32) -> Vec<f32> {
    let mut x = vec![0.0f32; n];
    rng.fill_activations(&mut x, spread);
    // salt edge cases
    if n >= 4 {
        x[0] = 0.0;
        x[1] = -0.0;
        let i = 2 + rng.below(n - 2);
        x[i] = if rng.f32() < 0.5 { 3.0e38 } else { 1.0e-38 };
    }
    x
}

/// Quantization must be *idempotent*: re-quantizing its own output
/// changes nothing (the output lies on the representable grid).
#[test]
fn prop_quantize_idempotent() {
    let mut rng = Rng::new(101);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(16));
        let spread = rng.range_f32(0.5, 6.0);
        let mut x = data(&mut rng, n, spread);
        c.fake_quantize(&mut x);
        let once = x.clone();
        c.fake_quantize(&mut x);
        assert_eq!(once, x, "case {case} scheme {}", s.name());
    }
}

/// decode(encode(x)) == fake_quantize(x) for every scheme: the wire
/// path and the in-place error-injection path are the same function.
#[test]
fn prop_wire_equals_fake_quantize() {
    let mut rng = Rng::new(202);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(16));
        let spread = rng.range_f32(0.5, 6.0);
        let x = data(&mut rng, n, spread);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        // wire layout: bit-packed codes + one scale byte per block
        let expect = (n * s.elem.bits() as usize).div_ceil(8) + n / s.block;
        assert_eq!(wire.len(), expect, "case {case}");
        let decoded = c.decode(&wire, n);
        let mut fq = x.clone();
        c.fake_quantize(&mut fq);
        assert_eq!(decoded, fq, "case {case} scheme {}", s.name());
    }
}

/// Dequantized outputs never exceed the block's representable maximum
/// and are always finite.
#[test]
fn prop_outputs_bounded_finite() {
    let mut rng = Rng::new(303);
    for _ in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(8));
        let mut x = data(&mut rng, n, 8.0);
        c.fake_quantize(&mut x);
        for v in &x {
            assert!(v.is_finite());
        }
    }
}

/// Sign symmetry: quantize(-x) == -quantize(x).
#[test]
fn prop_sign_symmetry() {
    let mut rng = Rng::new(404);
    for _ in 0..40 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(8));
        let x = data(&mut rng, n, 3.0);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        let mut a = x.clone();
        let mut b = neg.clone();
        c.fake_quantize(&mut a);
        c.fake_quantize(&mut b);
        for (p, q) in a.iter().zip(&b) {
            assert_eq!(*p, -*q);
        }
    }
}

/// More effective bits never hurt (on average): for the same block
/// size, fp5 MSE <= fp4 MSE <= fp3 MSE on random activation data.
#[test]
fn prop_bits_monotone_mse() {
    let mut rng = Rng::new(505);
    for _ in 0..10 {
        let n = 32 * 64;
        let x = data(&mut rng, n, 3.0);
        let mut prev = 0.0f64;
        for elem in ["fp5_e2m2", "fp4_e2m1", "fp3_e1m1"] {
            let c = MxCodec::new(MxScheme::new(elem, 32, 8).unwrap());
            let mut y = x.clone();
            c.fake_quantize(&mut y);
            let mse: f64 = x
                .iter()
                .zip(&y)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>();
            // error grows as precision shrinks: fp5 <= fp4 <= fp3
            assert!(mse * 1.001 >= prev, "{elem}: {mse} < {prev}");
            prev = mse;
        }
    }
}

/// Collective linearity: reduce(x, parts) - x == sum of decode(parts)
/// regardless of worker count, and the uncompressed path is exact.
#[test]
fn prop_collective_linear_uncompressed() {
    let mut rng = Rng::new(606);
    let link = LinkModel { alpha_s: 0.0, beta_bytes_per_s: 1e9 };
    for _ in 0..20 {
        let n = 32 * (1 + rng.below(8));
        let tp = [1usize, 2, 4, 8][rng.below(4)];
        let x = data(&mut rng, n, 1.0);
        let parts: Vec<Vec<f32>> = (0..tp).map(|_| data(&mut rng, n, 1.0)).collect();
        let mut out = Vec::new();
        let mut wire = Vec::new();
        all_gather_reduce_add(&x, &parts, None, &link, &mut out, &mut wire);
        for i in 0..n {
            let want: f32 = x[i] + parts.iter().map(|p| p[i]).sum::<f32>();
            assert!((out[i] - want).abs() <= 1e-4 * want.abs().max(1.0));
        }
    }
}

/// Wire size accounting: the packed wire is exactly the analytic size
/// and strictly smaller than fp16 for every MX scheme.
#[test]
fn prop_wire_size_exact() {
    let mut rng = Rng::new(707);
    for _ in 0..40 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = s.block * (1 + rng.below(32));
        let x = data(&mut rng, n, 2.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let nblocks = n / s.block;
        let expect = (n * s.elem.bits() as usize).div_ceil(8) + nblocks;
        assert_eq!(wire.len(), expect, "{}", s.name());
        assert!(c.wire_bytes(n) <= n * 2, "never larger than fp16: {}", s.name());
        // analytic effective bits match the scheme definition
        assert!((c.effective_bits(n) - s.effective_bits()).abs() < 1e-12);
    }
}

/// Odd (non-block-multiple) length, including the empty slice: pick
/// anything in [0, 5·block + block-1).
fn odd_len(rng: &mut Rng, block: usize) -> usize {
    rng.below(5 * block + block.max(2) - 1)
}

/// Wire-level encode∘decode idempotence, odd lengths included: the
/// decoded tensor lies on the representable grid, so a second wire
/// round trip reproduces it bit-for-bit — for both the fast codec and
/// the reference oracle.
#[test]
fn prop_wire_roundtrip_idempotent() {
    let mut rng = Rng::new(808);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let n = odd_len(&mut rng, s.block);
        let x = data(&mut rng, n, rng.range_f32(0.5, 6.0));
        for c in [&MxCodec::new(s) as &dyn Compressor, &RefMxCodec::new(s)] {
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            let once = c.decode(&wire, n);
            let mut wire2 = Vec::new();
            c.encode(&once, &mut wire2);
            let twice = c.decode(&wire2, n);
            for (i, (a, b)) in once.iter().zip(&twice).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "case {case} {} [{i}]: {a:?} re-quantized to {b:?}",
                    c.name()
                );
            }
        }
    }
}

/// Every round-tripped element honors the analytic per-block error
/// bound from `MxScheme::block_error_bound` (the bound the perf model
/// and the paper's error analysis lean on), including tail blocks that
/// compute amax over fewer than `block` elements.
#[test]
fn prop_error_bound_analytic() {
    let mut rng = Rng::new(909);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let c = MxCodec::new(s);
        let n = odd_len(&mut rng, s.block);
        let x = data(&mut rng, n, rng.range_f32(0.5, 8.0));
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let dec = c.decode(&wire, n);
        for (bi, blk) in x.chunks(s.block).enumerate() {
            let amax = blk.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let bound = s.block_error_bound(amax);
            for (i, (a, d)) in blk.iter().zip(&dec[bi * s.block..]).enumerate() {
                let err = (a - d).abs();
                assert!(
                    err <= bound * (1.0 + 1e-6),
                    "case {case} scheme {} block {bi} elem {i}: |{a} - {d}| = {err} > bound {bound} (amax {amax})",
                    s.name()
                );
            }
        }
    }
}

/// `requant_add` (the Analytic-mode path that skips bit-packing) is
/// bit-equal to the packed path (`encode` + `decode_add`) on the same
/// seeded accumulator — fast codec and oracle alike, odd lengths
/// included. This is the equivalence that lets the collective engine
/// swap modes without changing numerics.
#[test]
fn prop_requant_equals_packed_roundtrip() {
    let mut rng = Rng::new(1010);
    for case in 0..60 {
        let s = schemes(&mut rng);
        let n = odd_len(&mut rng, s.block);
        let x = data(&mut rng, n, rng.range_f32(0.5, 6.0));
        let seed_acc: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        for c in [&MxCodec::new(s) as &dyn Compressor, &RefMxCodec::new(s)] {
            let mut packed = seed_acc.clone();
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            c.decode_add(&wire, n, &mut packed);
            let mut analytic = seed_acc.clone();
            let mut scratch = Vec::new();
            c.requant_add(&x, &mut analytic, &mut scratch);
            for (i, (p, a)) in packed.iter().zip(&analytic).enumerate() {
                assert!(
                    p.to_bits() == a.to_bits(),
                    "case {case} {} [{i}]: packed {p:?} vs analytic {a:?}",
                    c.name()
                );
            }
        }
    }
}

/// Replay the committed shrunk-regression corpus (`tests/corpus/*.json`)
/// through the full differential harness: each file is a fuzz finding
/// (or a hand-written hostile case) reduced to `scheme` + raw input
/// bits, and must stay green forever.
#[test]
fn corpus_regressions_replay() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus");
    let mut files: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("read {}: {e}", dir.display()))
        .filter_map(|e| {
            let p = e.ok()?.path();
            (p.extension()? == "json").then_some(p)
        })
        .collect();
    files.sort();
    assert!(files.len() >= 8, "corpus shrank: {} files in {}", files.len(), dir.display());
    for path in files {
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap())
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let name = doc.get("scheme").and_then(|s| s.as_str()).expect("corpus: scheme");
        let scheme = MxScheme::parse(name).unwrap();
        let x: Vec<f32> = doc
            .get("x_bits")
            .and_then(|v| v.as_arr())
            .expect("corpus: x_bits")
            .iter()
            .map(|b| f32::from_bits(u32::from_str_radix(b.as_str().unwrap(), 16).unwrap()))
            .collect();
        fuzz::differential_slice(&x, scheme);
        println!("corpus ok: {} ({} values, {name})", path.display(), x.len());
    }
}
