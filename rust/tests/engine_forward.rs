//! End-to-end engine integration: the rust coordinator executing the AOT
//! stage artifacts must reproduce the python staged-forward oracle
//! (artifacts/golden/forward), uncompressed and compressed, and the
//! decode path must agree with prefill.

use std::path::PathBuf;

use tpcc::model::weights::Weights;
use tpcc::runtime::Runtime;
use tpcc::tp::{BatchKv, EngineOptions, TpEngine};
use tpcc::util::npy::Npy;

fn artifacts() -> Option<PathBuf> {
    let d = tpcc::artifacts_dir();
    d.join("manifest.json").exists().then_some(d)
}

fn make_engine(compress: &str) -> Option<TpEngine> {
    let root = artifacts()?;
    let rt = Runtime::load(&root).unwrap();
    let weights = Weights::load(&root.join("weights/nano")).unwrap();
    let opts = EngineOptions::new("nano", 2).with_compress(compress);
    Some(TpEngine::new(rt, &weights, opts).unwrap())
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn prefill_matches_python_oracle_uncompressed() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let tokens = Npy::load(&root.join("golden/forward/tokens.npy")).unwrap();
    let want = Npy::load(&root.join("golden/forward/logits_tp2.npy")).unwrap();
    let toks = tokens.as_i32().unwrap();
    let (b, s) = (tokens.shape[0], tokens.shape[1]);

    let mut eng = make_engine("none").unwrap();
    let (logits, timing) = eng.prefill(&toks, b, s, &vec![0; b], None).unwrap();
    let wantv = want.as_f32().unwrap();
    assert_eq!(logits.len(), wantv.len());
    let d = max_abs_diff(&logits, &wantv);
    assert!(d < 2e-3, "uncompressed logits differ from python oracle by {d}");
    assert!(timing.compute_s > 0.0);
    // uncompressed wire = fp16 raw baseline
    assert_eq!(timing.wire_bytes, timing.raw_bytes);
    assert!(timing.wire_bytes > 0);
}

#[test]
fn prefill_matches_python_oracle_fp4_compressed() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let tokens = Npy::load(&root.join("golden/forward/tokens.npy")).unwrap();
    let want = Npy::load(&root.join("golden/forward/logits_tp2_fp4.npy")).unwrap();
    let toks = tokens.as_i32().unwrap();
    let (b, s) = (tokens.shape[0], tokens.shape[1]);

    let mut eng = make_engine("fp4_e2m1_b32_e8m0").unwrap();
    let (logits, timing) = eng.prefill(&toks, b, s, &vec![0; b], None).unwrap();
    let wantv = want.as_f32().unwrap();
    let d = max_abs_diff(&logits, &wantv);
    assert!(d < 5e-3, "fp4 logits differ from python oracle by {d}");
    // wire accounting: compressed shards must be smaller than fp16 raw
    assert!(timing.wire_bytes > 0 && timing.wire_bytes < timing.raw_bytes / 3);
}

#[test]
fn decode_agrees_with_prefill() {
    let Some(_) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let mut eng = make_engine("none").unwrap();
    let cfg = eng.cfg.clone();

    // prompt of 15 tokens: prefill 15 (bucket 16 with 1 pad), then
    // compare: full prefill of 16 vs prefill 15 + decode of token 16.
    let prompt: Vec<i32> = (0..16).map(|i| (i * 7 + 3) % 256).collect();

    // full prefill (bucket 16)
    let (full_logits, _) = eng.prefill(&prompt, 1, 16, &[0], None).unwrap();
    let v = cfg.vocab;
    let last_full = &full_logits[15 * v..16 * v];

    // prefill first 15 (padded to 16), keep kv, then decode token #16
    let mut padded = prompt.clone();
    padded[15] = 0;
    let mut kv = BatchKv::new(&cfg, 2, 1);
    let (_, _) = eng.prefill(&padded, 1, 16, &[0], Some(&mut kv)).unwrap();
    // NOTE: the pad token wrote garbage at position 15; decode of the
    // real token 16 at pos 15 overwrites it before it becomes visible.
    let (dec_logits, _) = eng.decode(&[prompt[15]], &[15], &mut kv).unwrap();
    assert_eq!(dec_logits.len(), v);

    let d = max_abs_diff(last_full, &dec_logits);
    assert!(d < 2e-3, "decode diverges from prefill by {d}");
}

#[test]
fn tp_degrees_agree() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let rt1 = Runtime::load(&root).unwrap();
    let weights = Weights::load(&root.join("weights/nano")).unwrap();
    let mut e1 = TpEngine::new(rt1, &weights, EngineOptions::new("nano", 1)).unwrap();
    let mut e4 = make_engine_tp(&root, 4);

    let prompt: Vec<i32> = (0..128).map(|i| (i * 13 + 11) % 256).collect();
    let (l1, _) = e1.prefill(&prompt, 1, 128, &[0], None).unwrap();
    let (l4, _) = e4.prefill(&prompt, 1, 128, &[0], None).unwrap();
    let d = max_abs_diff(&l1, &l4);
    assert!(d < 2e-3, "tp=1 vs tp=4 logits differ by {d}");
}

fn make_engine_tp(root: &PathBuf, tp: usize) -> TpEngine {
    let rt = Runtime::load(root).unwrap();
    let weights = Weights::load(&root.join("weights/nano")).unwrap();
    TpEngine::new(rt, &weights, EngineOptions::new("nano", tp)).unwrap()
}

/// Engine-level fused path: an engine with `fused=true` must produce
/// the same logits as the host-codec engine (same scheme), proving the
/// on-accelerator Pallas compression composes end-to-end.
#[test]
fn fused_engine_matches_host_codec_engine() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let prompt: Vec<i32> = (0..128).map(|i| (i * 11 + 5) % 256).collect();
    let mut outs = Vec::new();
    for fused in [false, true] {
        let rt = Runtime::load(&root).unwrap();
        let weights = Weights::load(&root.join("weights/nano")).unwrap();
        let opts = EngineOptions::new("nano", 2)
            .with_compress("fp4_e2m1_b32_e8m0")
            .with_fused(fused);
        let mut eng = TpEngine::new(rt, &weights, opts).unwrap();
        let (logits, t) = eng.prefill(&prompt, 1, 128, &[0], None).unwrap();
        // both paths account the same packed wire size
        assert!(t.wire_bytes > 0 && t.wire_bytes < t.raw_bytes / 3);
        outs.push((logits, t.wire_bytes));
    }
    assert_eq!(outs[0].1, outs[1].1, "wire accounting differs");
    let d = max_abs_diff(&outs[0].0, &outs[1].0);
    assert!(d < 1e-4, "fused engine differs from host codec engine by {d}");
}

/// The fused Pallas path: quantize and dequant+reduce+add as AOT HLO
/// executables (paper Fig. 1b fused into the graph) must agree exactly
/// with the rust codec doing the same collective on the host — this is
/// the L1<->L3 contract that lets the sweeps use the rust codec.
#[test]
fn fused_path_matches_rust_codec() {
    let Some(root) = artifacts() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    use tpcc::mxfmt::{Compressor, MxCodec, MxScheme};
    use tpcc::runtime::{lit_f32, lit_u8, to_vec_f32, to_vec_u8};
    use tpcc::util::rng::Rng;

    let rt = Runtime::load(&root).unwrap();
    let (b, s, d, tp) = (1usize, 128usize, 128usize, 2usize); // nano dims
    let scheme = MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap();
    let codec = MxCodec::new(scheme);
    let mut rng = Rng::new(13);

    // two ranks' partial activations + the residual x
    let mut x = vec![0.0f32; b * s * d];
    rng.fill_activations(&mut x, 1.0);
    let mut parts = vec![vec![0.0f32; b * s * d]; tp];
    for p in &mut parts {
        rng.fill_activations(p, 2.0);
    }

    // --- HLO path: quantize each shard, stack, dequant_reduce_add ---
    let mut codes_all = Vec::new();
    let mut scales_all = Vec::new();
    for p in &parts {
        let out = rt
            .execute(
                "nano/quant_fp4_e2m1_b32_e8m0_b1_s128",
                &[lit_f32(&[b, s, d], p).unwrap()],
            )
            .unwrap();
        codes_all.extend(to_vec_u8(&out[0]).unwrap());
        scales_all.extend(to_vec_u8(&out[1]).unwrap());
    }
    let nb = d / 32;
    let out = rt
        .execute(
            "nano/dqra_fp4_e2m1_b32_e8m0_tp2_b1_s128",
            &[
                lit_f32(&[b, s, d], &x).unwrap(),
                lit_u8(&[tp, b, s, d], &codes_all).unwrap(),
                lit_u8(&[tp, b, s, nb], &scales_all).unwrap(),
            ],
        )
        .unwrap();
    let fused = to_vec_f32(&out[0]).unwrap();

    // --- rust codec path ---
    let mut acc = x.clone();
    let mut wire = Vec::new();
    for p in &parts {
        codec.encode(p, &mut wire);
        codec.decode_add(&wire, p.len(), &mut acc);
    }

    let d_max = max_abs_diff(&fused, &acc);
    assert!(d_max < 1e-5, "fused HLO vs rust codec differ by {d_max}");
}
