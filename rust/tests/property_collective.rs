//! Property tests for the collective engine: every algorithm must
//! compute `x + Σ partials` — exactly (up to f32 reassociation) under
//! `NoCompress`, and within the MX scheme's error bound under
//! compression — across world sizes {1, 2, 3, 4, 8} and
//! non-power-of-two message lengths.

use tpcc::collective::algo::{AlgoKind, CollectiveAlgo, ExecCtx};
use tpcc::collective::{pipeline, CommScratch, Topology};
use tpcc::interconnect::LinkModel;
use tpcc::mxfmt::{compressor_from_spec, Compressor, NoCompress};
use tpcc::util::rng::Rng;

const WORLDS: [usize; 5] = [1, 2, 3, 4, 8];
/// non-power-of-two lengths, multiples of every MX block size in play
const LENS: [usize; 3] = [96, 480, 1440];

fn topos_for(world: usize) -> Vec<Topology> {
    let intra = LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 64e9 };
    let inter = LinkModel { alpha_s: 3e-5, beta_bytes_per_s: 1.5e9 };
    let mut t = vec![Topology::flat(world, intra)];
    if world >= 4 && world % 2 == 0 {
        t.push(Topology::two_level(2, world / 2, intra, inter));
    }
    t
}

fn make_case(world: usize, len: usize, seed: u64) -> (Vec<f32>, Vec<Vec<f32>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; len];
    rng.fill_activations(&mut x, 1.0);
    let mut parts = vec![vec![0.0f32; len]; world];
    for p in &mut parts {
        rng.fill_activations(p, 2.0);
    }
    // exact sum in f64
    let mut exact = vec![0.0f64; len];
    for i in 0..len {
        exact[i] = x[i] as f64;
        for p in &parts {
            exact[i] += p[i] as f64;
        }
    }
    (x, parts, exact)
}

fn rel_l2(out: &[f32], exact: &[f64]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (o, e) in out.iter().zip(exact) {
        num += (*o as f64 - e).powi(2);
        den += e.powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

fn run_algo(
    kind: AlgoKind,
    x: &[f32],
    parts: &[Vec<f32>],
    comp: Option<&dyn Compressor>,
    topo: &Topology,
) -> Vec<f32> {
    let ctx = ExecCtx { comp, topo, measure: true };
    let refs: Vec<&[f32]> = parts.iter().map(Vec::as_slice).collect();
    let mut out = Vec::new();
    let mut scratch = CommScratch::default();
    let rep = kind.implementation().run(x, &refs, &ctx, &mut out, &mut scratch);
    assert_eq!(rep.algo, kind.name());
    assert_eq!(out.len(), x.len(), "{:?}: wrong output length", kind);
    out
}

#[test]
fn every_algorithm_is_exact_under_nocompress() {
    for world in WORLDS {
        for len in LENS {
            let (x, parts, exact) = make_case(world, len, (world * 1000 + len) as u64);
            for topo in topos_for(world) {
                for kind in AlgoKind::ALL {
                    if !kind.supports(world, &topo) {
                        continue;
                    }
                    let out = run_algo(kind, &x, &parts, Some(&NoCompress), &topo);
                    // NoCompress moves exact f32 payloads; only summation
                    // order differs between algorithms
                    let rel = rel_l2(&out, &exact);
                    assert!(
                        rel < 1e-6,
                        "{kind:?} world={world} len={len} nodes={}: rel {rel}",
                        topo.nodes
                    );
                }
            }
        }
    }
}

#[test]
fn none_and_nocompress_agree_per_algorithm() {
    for world in WORLDS {
        let len = LENS[1];
        let (x, parts, _) = make_case(world, len, world as u64);
        for topo in topos_for(world) {
            for kind in AlgoKind::ALL {
                if !kind.supports(world, &topo) {
                    continue;
                }
                let a = run_algo(kind, &x, &parts, None, &topo);
                let b = run_algo(kind, &x, &parts, Some(&NoCompress), &topo);
                // identical summation order -> bitwise equal
                assert_eq!(a, b, "{kind:?} world={world} nodes={}", topo.nodes);
            }
        }
    }
}

#[test]
fn gather_algorithms_are_bit_identical() {
    // ring and recursive doubling move the same quantized payloads;
    // only the link schedule differs, so outputs must match bitwise
    let c = compressor_from_spec("fp4_e2m1_b32_e8m0").unwrap();
    for world in [1usize, 2, 4, 8] {
        for len in LENS {
            let (x, parts, _) = make_case(world, len, (world * 31 + len) as u64);
            let topo = Topology::flat(
                world,
                LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9 },
            );
            let a = run_algo(AlgoKind::FlatRing, &x, &parts, Some(c.as_ref()), &topo);
            let b = run_algo(AlgoKind::RecursiveDoubling, &x, &parts, Some(c.as_ref()), &topo);
            assert_eq!(a, b, "world={world} len={len}");
        }
    }
}

#[test]
fn every_algorithm_is_within_mx_error_bound() {
    // single-quantization algorithms (gather family) see one rounding
    // per value; two-shot and hierarchical re-quantize reduced values,
    // doubling the worst-case error.
    for (scheme, single_bound) in [("fp4_e2m1_b32_e8m0", 0.20), ("fp5_e2m2_b16_e8m0", 0.12)] {
        let c = compressor_from_spec(scheme).unwrap();
        for world in WORLDS {
            for len in LENS {
                let (x, parts, exact) =
                    make_case(world, len, (world * 7 + len * 3) as u64);
                for topo in topos_for(world) {
                    for kind in AlgoKind::ALL {
                        if !kind.supports(world, &topo) {
                            continue;
                        }
                        let out = run_algo(kind, &x, &parts, Some(c.as_ref()), &topo);
                        let bound = match kind {
                            AlgoKind::FlatRing | AlgoKind::RecursiveDoubling => single_bound,
                            AlgoKind::TwoShot | AlgoKind::Hierarchical => single_bound * 2.0,
                        };
                        let rel = rel_l2(&out, &exact);
                        assert!(
                            rel < bound,
                            "{scheme} {kind:?} world={world} len={len} nodes={}: rel {rel} > {bound}",
                            topo.nodes
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn analytic_and_measured_paths_agree_for_every_algorithm() {
    // the Analytic-mode requant path skips the wire round-trip but must
    // be bit-equal to the measured path for every algorithm's phases
    let c = compressor_from_spec("fp4_e2m1_b32_e8m0").unwrap();
    for world in [2usize, 3, 4, 8] {
        let len = LENS[2];
        let (x, parts, _) = make_case(world, len, world as u64 + 99);
        for topo in topos_for(world) {
            for kind in AlgoKind::ALL {
                if !kind.supports(world, &topo) {
                    continue;
                }
                let ctx_m = ExecCtx { comp: Some(c.as_ref()), topo: &topo, measure: true };
                let ctx_a = ExecCtx { comp: Some(c.as_ref()), topo: &topo, measure: false };
                let refs: Vec<&[f32]> = parts.iter().map(Vec::as_slice).collect();
                let (mut om, mut oa) = (Vec::new(), Vec::new());
                let mut scratch = CommScratch::default();
                let rm = kind.implementation().run(&x, &refs, &ctx_m, &mut om, &mut scratch);
                let ra = kind.implementation().run(&x, &refs, &ctx_a, &mut oa, &mut scratch);
                assert_eq!(om, oa, "{kind:?} world={world} nodes={}", topo.nodes);
                // link model is timing-mode independent
                assert_eq!(rm.link_s, ra.link_s);
                // measured codec work only exists in measured mode
                assert_eq!(ra.encode_s, 0.0);
                assert_eq!(ra.decode_s, 0.0);
            }
        }
    }
}

#[test]
fn odd_hidden_sizes_respect_block_alignment() {
    // Regression (failing-first against the old `aligned_slices`): when
    // the message length is NOT a multiple of the compressor's block,
    // slicing used to silently degrade to unit granularity, splitting
    // MX blocks across chunk boundaries and changing the quantization
    // grid. Chunked gather collectives must stay bit-identical to the
    // unchunked run for odd hidden sizes too — only the final slice may
    // carry the sub-block tail.
    let c = compressor_from_spec("fp4_e2m1_b32_e8m0").unwrap();
    let topo = Topology::flat(3, LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9 });
    for len in [100usize, 1438, 3 * 479] {
        let (x, parts, exact) = make_case(3, len, len as u64 ^ 0x0DD);
        let refs: Vec<&[f32]> = parts.iter().map(Vec::as_slice).collect();
        let ctx = ExecCtx { comp: Some(c.as_ref()), topo: &topo, measure: true };
        let algo = AlgoKind::FlatRing.implementation();
        let (mut mono, mut chunked) = (Vec::new(), Vec::new());
        let mut scratch = CommScratch::default();
        algo.run(&x, &refs, &ctx, &mut mono, &mut scratch);
        for chunks in [2usize, 3, 5] {
            let rep = pipeline::run_chunked(
                algo, &x, &refs, &ctx, chunks, &mut chunked, &mut scratch,
            );
            assert_eq!(
                mono, chunked,
                "tp=3 len={len} chunks={chunks}: chunk boundaries split an MX block                  (quantization grid changed vs the unchunked collective)"
            );
            assert!(rep.chunks >= 1);
        }
        // two-shot slices per rank internally — the odd tail must ride
        // the last slice, keeping every value on the global block grid
        // and the result within the scheme's error bound
        let out = run_algo(AlgoKind::TwoShot, &x, &parts, Some(c.as_ref()), &topo);
        let rel = rel_l2(&out, &exact);
        assert!(rel < 0.40, "two-shot tp=3 len={len}: rel {rel}");
    }
}
