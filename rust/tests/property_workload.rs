//! Property tests for the workload engine: streaming-histogram error
//! bounds and mergeability, and trace-generation determinism (the
//! acceptance pin: same seed + spec → bit-identical trace).

use tpcc::util::rng::Rng;
use tpcc::workload::stats::{LogHistogram, GROWTH};
use tpcc::workload::{Arrival, LenDist, Trace, TraceSpec};

fn spec(seed: u64) -> TraceSpec {
    TraceSpec {
        arrival: Arrival::Bursty { rate: 12.0, cv: 3.0 },
        prompt_len: LenDist::LogNormal { median: 48.0, sigma: 1.0, cap: 224 },
        output_len: LenDist::LogNormal { median: 16.0, sigma: 0.7, cap: 64 },
        requests: 300,
        seed,
    }
}

// ---------------------------------------------------------------------
// histogram: quantile within the bucket error bound
// ---------------------------------------------------------------------

/// For any recorded sample set and any percentile, the histogram's
/// answer is within one log bucket (relative factor GROWTH) of the
/// exact order statistic.
#[test]
fn histogram_quantiles_within_relative_bound() {
    // several distribution shapes, several seeds
    for (dist, seed) in [("uniform", 1u64), ("exp", 2), ("lognormal", 3), ("bimodal", 4)] {
        let mut rng = Rng::new(seed);
        let mut h = LogHistogram::new();
        let mut exact: Vec<f64> = Vec::new();
        for i in 0..5000 {
            let v = match dist {
                "uniform" => 1e-4 + rng.f64() * 2.0,
                "exp" => rng.exponential(10.0).max(1e-5),
                "lognormal" => 5e-3 * (rng.normal() as f64).exp(),
                _ => {
                    if i % 2 == 0 {
                        1e-3 + rng.f64() * 1e-3
                    } else {
                        1.0 + rng.f64()
                    }
                }
            };
            h.record(v);
            exact.push(v);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [1.0, 5.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9] {
            let rank = ((p / 100.0) * exact.len() as f64).ceil().max(1.0) as usize;
            let want = exact[rank.min(exact.len()) - 1];
            let got = h.percentile(p);
            assert!(
                got / want <= GROWTH + 1e-9 && want / got <= GROWTH + 1e-9,
                "{dist}/p{p}: histogram {got} vs exact {want}"
            );
        }
        assert_eq!(h.count() as usize, exact.len());
        let exact_mean = exact.iter().sum::<f64>() / exact.len() as f64;
        assert!((h.mean() - exact_mean).abs() < 1e-9, "mean drifted");
        assert_eq!(h.min(), exact[0]);
        assert_eq!(h.max(), *exact.last().unwrap());
    }
}

/// fraction_below is consistent with the exact sample fraction to
/// within one bucket of mass around the threshold.
#[test]
fn histogram_fraction_below_tracks_exact() {
    let mut rng = Rng::new(9);
    let mut h = LogHistogram::new();
    let mut vals = Vec::new();
    for _ in 0..4000 {
        let v = rng.exponential(4.0).max(1e-5);
        h.record(v);
        vals.push(v);
    }
    for thr in [0.05, 0.25, 0.5, 1.0] {
        let exact = vals.iter().filter(|&&v| v <= thr).count() as f64 / vals.len() as f64;
        // widen the threshold by one bucket either way for the bound
        let lo = vals.iter().filter(|&&v| v <= thr / GROWTH).count() as f64 / vals.len() as f64;
        let hi = vals.iter().filter(|&&v| v <= thr * GROWTH).count() as f64 / vals.len() as f64;
        let got = h.fraction_below(thr);
        assert!(
            (lo - 1e-12..=hi + 1e-12).contains(&got),
            "thr {thr}: got {got}, exact {exact} (bounds {lo}..{hi})"
        );
    }
}

// ---------------------------------------------------------------------
// histogram: merge == concat
// ---------------------------------------------------------------------

#[test]
fn histogram_merge_equals_concat() {
    let mut rng = Rng::new(17);
    // split one stream across 5 shards, merge them back
    let mut shards: Vec<LogHistogram> = (0..5).map(|_| LogHistogram::new()).collect();
    let mut whole = LogHistogram::new();
    for i in 0..8000 {
        let v = match i % 3 {
            0 => rng.exponential(50.0),
            1 => 0.1 + rng.f64(),
            _ => 1e-7 * (1.0 + rng.f64()), // exercises underflow
        };
        whole.record(v);
        shards[i % 5].record(v);
    }
    let mut merged = LogHistogram::new();
    for s in &shards {
        merged.merge(s);
    }
    assert_eq!(merged.count(), whole.count());
    assert_eq!(merged.min(), whole.min());
    assert_eq!(merged.max(), whole.max());
    for p in [0.1, 1.0, 10.0, 50.0, 90.0, 99.0, 99.9] {
        assert_eq!(merged.percentile(p), whole.percentile(p), "p{p}");
    }
    for thr in [1e-6, 1e-2, 0.5, 2.0] {
        assert_eq!(merged.fraction_below(thr), whole.fraction_below(thr), "thr {thr}");
    }
    assert!((merged.sum() - whole.sum()).abs() < 1e-6 * whole.sum().abs().max(1.0));
}

// ---------------------------------------------------------------------
// trace: determinism + replay round-trip
// ---------------------------------------------------------------------

/// Acceptance pin: the same seed + trace spec produces the
/// bit-identical trace, and a different seed does not.
#[test]
fn trace_generation_is_bit_identical_per_seed() {
    let a = spec(42).generate();
    let b = spec(42).generate();
    assert_eq!(a, b, "same spec+seed must be bit-identical");
    // f64 equality, not approximate: compare the raw bits too
    for (x, y) in a.events.iter().zip(&b.events) {
        assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
    }
    let c = spec(43).generate();
    assert_ne!(a, c, "different seeds must differ");
    // all arrival processes are deterministic, not just bursty
    for arrival in [
        Arrival::Poisson { rate: 8.0 },
        Arrival::Closed { concurrency: 4, think_s: 0.01 },
    ] {
        let s = TraceSpec { arrival, ..spec(7) };
        assert_eq!(s.generate(), s.generate());
    }
}

#[test]
fn trace_jsonl_roundtrip() {
    let t = spec(5).generate();
    let text = t.to_jsonl();
    assert_eq!(text.lines().count(), t.events.len());
    let back = Trace::parse_jsonl(&text).unwrap();
    assert_eq!(back.events, t.events, "JSONL round-trip must preserve the trace");
    assert!(back.closed_loop.is_none());
    // malformed inputs are rejected
    assert!(Trace::parse_jsonl("").is_err());
    assert!(Trace::parse_jsonl("{\"at_s\": \"soon\"}").is_err());
    assert!(Trace::parse_jsonl("{\"prompt_tokens\": 4}").is_err()); // no at_s
    // lengths are required and must be numeric and >= 1 — no silent
    // defaulting of a foreign trace to a 1-token workload
    assert!(Trace::parse_jsonl("{\"at_s\":0.5,\"prompt_tokens\":4}").is_err());
    assert!(
        Trace::parse_jsonl("{\"at_s\":0.5,\"prompt_tokens\":\"4\",\"max_new_tokens\":2}").is_err()
    );
    assert!(
        Trace::parse_jsonl("{\"at_s\":0.5,\"prompt_tokens\":0,\"max_new_tokens\":2}").is_err()
    );
    // unsorted input comes back sorted
    let shuffled = "{\"at_s\":2.0,\"prompt_tokens\":3,\"max_new_tokens\":4}\n\
                    {\"at_s\":1.0,\"prompt_tokens\":5,\"max_new_tokens\":6}\n";
    let s = Trace::parse_jsonl(shuffled).unwrap();
    assert!(s.events[0].at_s < s.events[1].at_s);
}

#[test]
fn trace_lengths_respect_caps() {
    let t = spec(11).generate();
    assert_eq!(t.events.len(), 300);
    for ev in &t.events {
        assert!((1..=224).contains(&ev.prompt_tokens));
        assert!((1..=64).contains(&ev.max_new_tokens));
        assert!(ev.at_s.is_finite() && ev.at_s >= 0.0);
    }
}
