//! HTTP substrate integration: the fixed worker pool must bound
//! concurrency under a connection burst — every connection gets an HTTP
//! answer (200 or a 503 shed), no unbounded thread spawning, and the
//! read-only endpoints keep working through the pool. Runs without AOT
//! artifacts via a detached coordinator handle.

use std::sync::Arc;

use tpcc::coordinator::CoordinatorHandle;
use tpcc::server::{http_get, http_post, Server};

fn bind_detached(workers: usize, backlog: usize) -> (Server, String, Arc<tpcc::server::PoolStats>) {
    let server = Server::bind("127.0.0.1:0", CoordinatorHandle::detached())
        .unwrap()
        .with_pool(workers, backlog);
    let addr = server.local_addr().unwrap().to_string();
    let stats = server.stats();
    (server, addr, stats)
}

#[test]
fn burst_is_bounded_and_fully_answered() {
    let burst = 32usize;
    let workers = 3usize;
    let (server, addr, stats) = bind_detached(workers, 4);
    let srv = std::thread::spawn(move || server.serve_n(burst).unwrap());

    // a synchronized burst: all clients connect at once
    let joins: Vec<_> = (0..burst)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || http_get(&addr, "/healthz").unwrap())
        })
        .collect();
    let mut ok = 0usize;
    let mut shed = 0usize;
    for j in joins {
        let (code, body) = j.join().unwrap();
        match code {
            200 => {
                assert!(body.contains("ok"));
                ok += 1;
            }
            503 => {
                assert!(body.contains("overloaded"), "{body}");
                shed += 1;
            }
            other => panic!("connection got status {other}: {body}"),
        }
    }
    srv.join().unwrap();
    // every connection was answered, one way or the other ...
    assert_eq!(ok + shed, burst);
    assert_eq!(stats.served() + stats.shed(), burst);
    assert_eq!(stats.served(), ok);
    // ... and the pool never ran more handlers than it has workers
    assert!(
        stats.peak_active() <= workers,
        "peak {} exceeded the {workers}-worker cap",
        stats.peak_active()
    );
    assert!(ok > 0, "pool served nothing");
}

#[test]
fn pool_serves_endpoints_and_404s() {
    let (server, addr, stats) = bind_detached(2, 8);
    let srv = std::thread::spawn(move || server.serve_n(4).unwrap());

    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ok"));

    // detached registry still serves a valid metrics snapshot
    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let m = tpcc::util::json::Json::parse(&body).unwrap();
    assert_eq!(m.get("requests_completed").unwrap().as_i64(), Some(0));

    let (code, _) = http_get(&addr, "/nope").unwrap();
    assert_eq!(code, 404);

    // /generate with no engine behind the handle answers 500, not a drop
    let (code, body) =
        http_post(&addr, "/generate", r#"{"prompt": "x", "max_tokens": 1}"#).unwrap();
    assert_eq!(code, 500, "{body}");
    assert!(body.contains("error"));

    srv.join().unwrap();
    assert_eq!(stats.served(), 4);
    assert_eq!(stats.shed(), 0);
}

#[test]
fn malformed_requests_still_answered_through_pool() {
    use std::io::{Read as _, Write as _};

    let (server, addr, _stats) = bind_detached(2, 8);
    let srv = std::thread::spawn(move || server.serve_n(2).unwrap());

    let (code, body) = http_post(&addr, "/generate", "{not json").unwrap();
    assert_eq!(code, 400, "{body}");

    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.write_all(b"\r\n").unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "got {raw:?}");

    srv.join().unwrap();
}
