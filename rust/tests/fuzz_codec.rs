//! Fixed-seed fuzz smoke — the CI face of the differential fuzz
//! harness in [`tpcc::mxfmt::fuzz`].
//!
//! Every PR runs `TPCC_FUZZ_ITERS` (default 500) deterministic
//! iterations of the two drivers the cargo-fuzz targets under
//! `rust/fuzz/` wrap:
//!
//! * `differential_case` — random values (specials, subnormals, NaN,
//!   ±Inf, odd lengths) through fast and reference codecs, asserting
//!   bit-identical wire bytes and decoded values;
//! * `decoder_case` — arbitrary / truncated / bit-flipped wire bytes
//!   through the validating decoder, which must error, never panic or
//!   touch memory out of bounds.
//!
//! Deterministic by construction (seeds are the iteration index), so
//! a failure here is reproducible by seed alone: rerun with
//! `tpcc::mxfmt::fuzz::differential_case(SEED)` in a unit test, or
//! feed the seed to the cargo-fuzz reproducer. For a deeper soak,
//! raise the env var: `TPCC_FUZZ_ITERS=200000 cargo test --test
//! fuzz_codec --release`.

fn iters() -> u64 {
    std::env::var("TPCC_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(500)
}

#[test]
fn differential_fuzz_smoke() {
    let n = iters();
    for seed in 0..n {
        tpcc::mxfmt::fuzz::differential_case(seed);
    }
    println!("differential fuzz: {n} cases, fast == reference on every wire");
}

#[test]
fn decoder_robustness_fuzz_smoke() {
    let n = iters();
    for seed in 0..n {
        tpcc::mxfmt::fuzz::decoder_case(seed);
    }
    println!("decoder fuzz: {n} cases, no panic / OOB on arbitrary wire bytes");
}
