//! Regenerates paper Table 5 (appendix): the hyper-parameter ablation
//! over scale bits, value dtype, block size and TP degree.

use tpcc::tables::{common, table5};

fn main() {
    let tokens = common::eval_tokens(2048);
    match table5::run(tokens) {
        Ok(rows) => table5::print(&rows),
        Err(e) => {
            eprintln!("table5 failed: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    }
}
