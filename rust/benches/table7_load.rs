//! Regenerates Table 7: serving under load — max sustainable QPS at a
//! TTFT SLO per {policy × hardware profile}, via the virtual-time load
//! driver over the modeled engine. Needs no artifacts.

use tpcc::tables::table7;

fn main() {
    let cfg = table7::Table7Config::default();
    match table7::run(&cfg) {
        Ok(rows) => table7::print(&rows, &cfg),
        Err(e) => {
            eprintln!("table7 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
