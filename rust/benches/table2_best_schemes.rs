//! Regenerates paper Table 2: best-scheme selection (<3% rule on the
//! train slice) evaluated on the held-out test split.

use tpcc::tables::{common, table2};

fn main() {
    let tokens = common::eval_tokens(4096);
    match table2::run(tokens) {
        Ok(rows) => table2::print(&rows),
        Err(e) => {
            eprintln!("table2 failed: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    }
}
