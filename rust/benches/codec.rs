//! Codec microbenchmarks: encode/decode throughput of the MX codec and
//! the Bian et al. baselines. The encode+decode path sits directly on
//! the TP collective (the paper's "compression overhead"), so these
//! numbers bound the achievable TTFT win — tracked in EXPERIMENTS.md
//! §Perf.

use tpcc::bench::{fmt_throughput, Bench};
use tpcc::mxfmt::{compressor_from_spec, Compressor};
use tpcc::util::rng::Rng;

fn main() {
    let n = 1 << 20; // 1M values = one 70B-scale partial (2x64xd8192)
    let mut rng = Rng::new(7);
    let mut x = vec![0.0f32; n];
    rng.fill_activations(&mut x, 3.0);

    let specs = [
        "fp4_e2m1_b32_e8m0",
        "fp4_e2m1_b8_e8m0",
        "fp5_e2m2_b32_e8m0",
        "fp3_e1m1_b32_e8m0",
        "int4_b32_e8m0",
        "int4_channelwise",
        "topk3",
        "fp16",
    ];

    Bench::header();
    let b = Bench::default();
    for spec in specs {
        let codec: Box<dyn Compressor> = compressor_from_spec(spec).unwrap();
        let mut wire = Vec::new();
        let r = b.run(&format!("encode/{spec}/1M"), || {
            codec.encode(&x, &mut wire);
            std::hint::black_box(&wire);
        });
        println!(
            "    -> {} ({} wire bytes, {:.2} eff bits)",
            fmt_throughput(n * 4, r.median_s),
            wire.len(),
            codec.effective_bits(n)
        );
        let mut acc = vec![0.0f32; n];
        let r = b.run(&format!("decode_add/{spec}/1M"), || {
            codec.decode_add(&wire, n, &mut acc);
            std::hint::black_box(&acc);
        });
        println!("    -> {}", fmt_throughput(n * 4, r.median_s));
    }
}
