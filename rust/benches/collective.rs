//! Collective-engine benchmark: every algorithm × message size × TP
//! degree × profile, at the shapes the TP layers actually produce.
//! The link component is simulated (per-algorithm α/β schedule over the
//! profile's topology); the codec component is real measured work
//! (median over reps via the Bench harness). After each cell group the
//! planner's pick is printed — `auto` is never slower (virtual time)
//! than the hard-coded flat ring.

use tpcc::bench::Bench;
use tpcc::collective::plan::{self, AlgoChoice};
use tpcc::collective::{execute, AlgoKind, CollectivePlan, CommScratch, Topology};
use tpcc::interconnect::HwProfile;
use tpcc::mxfmt::{compressor_from_spec, Compressor};
use tpcc::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let b = Bench::default();
    Bench::header();

    // message sizes: micro prefill 8x128xd192; paper-scale 2x128xd8192
    for (label, len) in [("8x128xd192", 8 * 128 * 192), ("2x128xd8192", 2 * 128 * 8192)] {
        for (prof_name, tp) in [("l4", 4usize), ("l4", 8), ("2x4l4", 8), ("2x4a100", 8)] {
            let profile = HwProfile::by_name(prof_name).unwrap();
            let topo = Topology::from_profile(profile, tp);
            let x = vec![0.0f32; len];
            let mut parts = vec![vec![0.0f32; len]; tp];
            for p in &mut parts {
                rng.fill_activations(p, 2.0);
            }
            for spec in ["none", "fp4_e2m1_b32_e8m0"] {
                let comp: Option<Box<dyn Compressor>> = if spec == "none" {
                    None
                } else {
                    Some(compressor_from_spec(spec).unwrap())
                };
                let mut ring_virtual = f64::NAN;
                for kind in AlgoKind::ALL {
                    if !kind.supports(tp, &topo) {
                        continue;
                    }
                    let p = CollectivePlan {
                        algo: kind,
                        chunks: 1,
                        est_total_s: 0.0,
                        est_link_s: 0.0,
                        est_codec_s: 0.0,
                    };
                    let mut out = Vec::new();
                    let mut scratch = CommScratch::default();
                    let mut last = None;
                    b.run(
                        &format!("{}/{label}/tp{tp}/{prof_name}/{spec}", kind.name()),
                        || {
                            let rep = execute(
                                &p, &x, &parts, comp.as_deref(), &topo, true, &mut out, &mut scratch,
                            );
                            std::hint::black_box(&out);
                            last = Some(rep);
                        },
                    );
                    let rep = last.unwrap();
                    let virt = rep.total_s();
                    if kind == AlgoKind::FlatRing {
                        ring_virtual = virt;
                    }
                    println!(
                        "    -> codec(work) {:.3}ms + link(model) {:.3}ms = virtual {:.3}ms",
                        (rep.encode_s + rep.decode_s) * 1e3,
                        rep.link_s * 1e3,
                        virt * 1e3
                    );
                }
                let auto = plan::choose(
                    len,
                    tp,
                    comp.as_deref(),
                    &topo,
                    profile.quant_values_per_s,
                    AlgoChoice::Auto,
                );
                let ring_est = plan::ring_baseline(
                    len,
                    tp,
                    comp.as_deref(),
                    &topo,
                    profile.quant_values_per_s,
                );
                println!(
                    "    planner: {} x{} — est {:.3}ms vs ring est {:.3}ms ({:.2}x); measured ring {:.3}ms",
                    auto.algo.name(),
                    auto.chunks,
                    auto.est_total_s * 1e3,
                    ring_est * 1e3,
                    ring_est / auto.est_total_s,
                    ring_virtual * 1e3
                );
                assert!(
                    auto.est_total_s <= ring_est + 1e-12,
                    "planner regressed vs flat ring"
                );
            }
        }
    }
}
