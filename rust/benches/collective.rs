//! Collective benchmark: all-gather + (de)compress + reduce at the
//! message sizes the TP layers actually produce, across TP degrees.
//! The link component is simulated (α+β model); the codec component is
//! real measured work.

use tpcc::bench::Bench;
use tpcc::collective::all_gather_reduce_add;
use tpcc::interconnect::HwProfile;
use tpcc::mxfmt::{compressor_from_spec, Compressor};
use tpcc::util::rng::Rng;

fn main() {
    let link = &HwProfile::by_name("l4").unwrap().link;
    let mut rng = Rng::new(3);

    Bench::header();
    let b = Bench::default();
    // message sizes: micro prefill 8x128xd192; paper-scale 2x128xd8192
    for (label, len) in [("8x128xd192", 8 * 128 * 192), ("2x128xd8192", 2 * 128 * 8192)] {
        for tp in [2usize, 4, 8] {
            let x = vec![0.0f32; len];
            let mut parts = vec![vec![0.0f32; len]; tp];
            for p in &mut parts {
                rng.fill_activations(p, 2.0);
            }
            for spec in ["none", "fp4_e2m1_b32_e8m0"] {
                let comp: Option<Box<dyn Compressor>> = if spec == "none" {
                    None
                } else {
                    Some(compressor_from_spec(spec).unwrap())
                };
                let mut out = Vec::new();
                let mut wire = Vec::new();
                let mut link_s = 0.0;
                let r = b.run(&format!("allgather/{label}/tp{tp}/{spec}"), || {
                    let rep = all_gather_reduce_add(
                        &x,
                        &parts,
                        comp.as_deref(),
                        link,
                        &mut out,
                        &mut wire,
                    );
                    link_s = rep.link_s;
                    std::hint::black_box(&out);
                });
                println!(
                    "    -> codec(work) {:.3}ms + link(model) {:.3}ms",
                    r.median_s * 1e3,
                    link_s * 1e3
                );
            }
        }
    }
}
