//! Regenerates paper Table 4: MX4 vs Bian et al. channel-wise INT4 and
//! TopK-3x (perplexity on the test split + TTFT speedups).

use tpcc::tables::{common, table4};

fn main() {
    let tokens = common::eval_tokens(4096);
    match table4::run(tokens) {
        Ok(t) => table4::print(&t),
        Err(e) => {
            eprintln!("table4 failed: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    }
}
