//! Regenerates paper Table 1: the compression-scheme grid search
//! (PPL degradation on the train slice). Token budget via
//! TPCC_EVAL_TOKENS (default 4096).

use tpcc::tables::{common, table1};

fn main() {
    let tokens = common::eval_tokens(4096);
    match table1::run(tokens) {
        Ok(t) => table1::print(&t),
        Err(e) => {
            eprintln!("table1 failed: {e:#} (run `make artifacts` first)");
            std::process::exit(1);
        }
    }
}
