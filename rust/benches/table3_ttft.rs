//! Regenerates paper Table 3: TTFT with and without communication
//! compression — analytic paper-scale deployments plus live CPU-PJRT
//! runs of the trained models under the simulated interconnects.

use tpcc::tables::table3;

fn main() {
    let rows = table3::run_analytic();
    table3::print(&rows, "analytic, paper-scale");
    table3::print_algo_ablation(&table3::run_algo_ablation());

    let reps = std::env::var("TPCC_TTFT_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let mut live = Vec::new();
    for (profile, tp) in [("l4", 2), ("l4", 4), ("a100", 4)] {
        // measured-overhead row (this CPU's codec/link regime) and
        // analytic row (rescaled to the target accelerator's roofline +
        // quantizer throughput — the paper's regime)
        for analytic in [false, true] {
            match table3::run_live(profile, tp, 8, 128, reps, analytic) {
                Ok(r) => live.push(r),
                Err(e) => eprintln!("live row {profile}/tp{tp} failed: {e:#}"),
            }
        }
    }
    if !live.is_empty() {
        table3::print(&live, "live, micro model on CPU PJRT (median of reps)");
    }
}
