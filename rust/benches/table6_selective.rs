//! Regenerates Table 6: the selective-compression ablation (uniform vs
//! paper vs auto per-site policies) over the analytic deployments.
//! Needs no artifacts — the cost model is the collective planner plus
//! a synthetic per-site calibration.

use tpcc::tables::table6;

fn main() {
    match table6::run_analytic() {
        Ok(rows) => table6::print(&rows),
        Err(e) => {
            eprintln!("table6 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
