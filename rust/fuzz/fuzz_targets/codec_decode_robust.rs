//! cargo-fuzz target: decoder robustness on untrusted wire bytes.
//!
//! The byte string head claims an `n_values` (deliberately decoupled
//! from the actual byte count — the decoder must length-check, never
//! trust the caller); the rest is fed verbatim as wire bytes to every
//! codec family's validating `try_decode_add`. Returning `Err` is
//! fine; panicking or reading/writing out of bounds is the finding
//! (run under ASan via `cargo fuzz run codec_decode_robust` to catch
//! the latter even where safe Rust wouldn't panic).

#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let Some((&[a, b], wire)) = data.split_first_chunk::<2>() else {
        return;
    };
    // up to 64 Ki claimed values — far beyond any wire the fuzzer
    // sends, so the truncation paths get constant exercise
    let n_values = u16::from_le_bytes([a, b]) as usize;
    tpcc::mxfmt::fuzz::decoder_arbitrary_bytes(wire, n_values);
});
