//! cargo-fuzz target: differential fast-vs-reference codec check.
//!
//! The fuzzer's byte string is the whole test case: the head picks the
//! scheme (element format × block × scale width), the tail is
//! reinterpreted as raw f32 bit patterns — so libFuzzer mutates the
//! *exact* input floats, including NaN payloads, ±Inf, subnormals and
//! ±0, and coverage feedback steers it into the codec's branch
//! structure. Odd tail lengths fall out of arbitrary byte counts.
//!
//! Any divergence between `MxCodec` and the `RefMxCodec` oracle —
//! wire bytes, decode_add bits, requant bits, stored-length
//! accounting, truncation acceptance — panics inside
//! `differential_slice` and becomes a reproducible finding.

#![no_main]

use libfuzzer_sys::fuzz_target;
use tpcc::mxfmt::fuzz::{FUZZ_BLOCKS, FUZZ_SCALE_EBITS};
use tpcc::mxfmt::{ELEM_FORMATS, MxScheme};

fuzz_target!(|data: &[u8]| {
    let Some((&[e, b, s], rest)) = data.split_first_chunk::<3>() else {
        return;
    };
    let elem = &ELEM_FORMATS[e as usize % ELEM_FORMATS.len()];
    let block = FUZZ_BLOCKS[b as usize % FUZZ_BLOCKS.len()];
    let ebits = FUZZ_SCALE_EBITS[s as usize % FUZZ_SCALE_EBITS.len()];
    let scheme = MxScheme::new(elem.name, block, ebits).expect("interned format");

    // cap the slice so one case stays fast; 4 KiB of input is plenty
    // to cover multi-block layouts at every block size
    let rest = &rest[..rest.len().min(4096)];
    let x: Vec<f32> = rest
        .chunks(4)
        .map(|c| {
            let mut w = [0u8; 4];
            w[..c.len()].copy_from_slice(c); // short tail chunk zero-padded
            f32::from_bits(u32::from_le_bytes(w))
        })
        .collect();
    tpcc::mxfmt::fuzz::differential_slice(&x, scheme);
});
