//! Build-time stamp for `tpcc_build_info`: best-effort short git sha in
//! the `TPCC_GIT_SHA` env var. Never load-bearing — when git (or the
//! .git dir) is unavailable the var is left empty and the runtime
//! reports "unknown" (`crate::metrics::build_git`).

use std::process::Command;

fn main() {
    let sha = Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_default();
    println!("cargo:rustc-env=TPCC_GIT_SHA={sha}");
    // restamp when the checked-out commit moves, not on every build
    println!("cargo:rerun-if-changed=../.git/HEAD");
}
