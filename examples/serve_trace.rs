//! End-to-end serving validation (DESIGN.md): replay a synthetic request
//! trace (Poisson arrivals, mixed prompt lengths sampled from the test
//! corpus) through the full coordinator — continuous batcher, KV-cache
//! pool, TP engine — once with uncompressed collectives and once with
//! the paper's FP4 scheme. Reports TTFT/TPOT/throughput percentiles.
//!
//!     cargo run --release --example serve_trace -- --requests 24 --rate 4

use std::time::Instant;

use tpcc::coordinator::{spawn, CoordinatorOptions, GenRequest};
use tpcc::model::weights::Weights;
use tpcc::runtime::Runtime;
use tpcc::tables::common;
use tpcc::tp::{EngineOptions, TpEngine};
use tpcc::util::cli::Args;
use tpcc::util::rng::Rng;

struct TraceResult {
    compress: String,
    ttft_p50: f64,
    ttft_p95: f64,
    tpot_p50: f64,
    throughput_tok_s: f64,
    wire_mb: f64,
    saved_mb: f64,
}

fn run_trace(compress: &str, n_requests: usize, rate_per_s: f64) -> anyhow::Result<TraceResult> {
    let corpus = common::corpus("test")?;
    let spec = compress.to_string();
    let (handle, join) = spawn(
        move || {
            let root = common::artifacts_root()?;
            let rt = Runtime::load(&root)?;
            let weights = Weights::load(&root.join("weights/micro"))?;
            TpEngine::new(
                rt,
                &weights,
                EngineOptions::new("micro", 2)
                    .with_compress(&spec)
                    .with_profile("l4"),
            )
        },
        CoordinatorOptions { decode_batch: 8, ..Default::default() },
    )?;

    let mut rng = Rng::new(42);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for _ in 0..n_requests {
        // prompt: random corpus slice of 16..200 bytes; 8..32 new tokens
        let len = 16 + rng.below(184);
        let start = rng.below(corpus.len() - 300);
        let prompt: String = corpus[start..].chars().take(len).collect();
        let max_new = 8 + rng.below(24);
        pending.push(handle.submit(GenRequest {
            prompt,
            max_new_tokens: max_new,
            greedy: true,
            stop_token: -1,
        }));
        std::thread::sleep(std::time::Duration::from_secs_f64(
            rng.exponential(rate_per_s),
        ));
    }
    let mut total_tokens = 0usize;
    for rx in pending {
        let resp = rx.recv()?;
        total_tokens += resp.new_tokens;
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = handle.metrics.clone();
    let ttft = m.ttft.snapshot();
    let tpot = m.tpot.snapshot();
    let out = TraceResult {
        compress: compress.to_string(),
        ttft_p50: ttft.percentile(50.0),
        ttft_p95: ttft.percentile(95.0),
        tpot_p50: tpot.percentile(50.0),
        throughput_tok_s: total_tokens as f64 / wall,
        wire_mb: m.comm_bytes_sent.get() as f64 / 1e6,
        saved_mb: m.comm_bytes_saved.get() as f64 / 1e6,
    };
    handle.shutdown();
    drop(handle);
    join.join().unwrap()?;
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("requests", 24);
    let rate = args.get_f64("rate", 4.0);
    println!("serve_trace: {n} requests, Poisson rate {rate}/s, micro model, TP=2, decode batch 8");

    let mut rows = Vec::new();
    for compress in ["none", "fp4_e2m1_b32_e8m0"] {
        println!("... replaying trace with compress={compress}");
        rows.push(run_trace(compress, n, rate)?);
    }

    println!(
        "\n{:<22} {:>10} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "compress", "ttft p50", "ttft p95", "tpot p50", "tok/s", "wire MB", "saved MB"
    );
    println!("{}", "-".repeat(92));
    for r in &rows {
        println!(
            "{:<22} {:>9.3}s {:>9.3}s {:>8.1}ms {:>12.1} {:>10.2} {:>10.2}",
            r.compress,
            r.ttft_p50,
            r.ttft_p95,
            r.tpot_p50 * 1e3,
            r.throughput_tok_s,
            r.wire_mb,
            r.saved_mb
        );
    }
    println!("\nserve_trace OK — record these rows in EXPERIMENTS.md");
    Ok(())
}
