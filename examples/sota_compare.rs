//! SoTA comparison driver — regenerates paper Table 4 (MX4 vs the Bian
//! et al. baselines: channel-wise INT4 and TopK-3x).
//!
//!     cargo run --release --example sota_compare -- [--tokens 4096]

use tpcc::tables::{common, table4};
use tpcc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let tokens = args.get_usize("tokens", common::eval_tokens(4096));
    let t = table4::run(tokens)?;
    table4::print(&t);
    Ok(())
}
