//! Quickstart: load the AOT artifacts, build a TP=2 engine over the
//! trained `micro` model, generate text, and show the per-layer
//! communication trace (the code-level realization of paper Fig. 1).
//!
//!     cargo run --release --example quickstart

use tpcc::coordinator::{spawn, CoordinatorOptions, GenRequest};
use tpcc::model::weights::Weights;
use tpcc::runtime::Runtime;
use tpcc::tables::common;
use tpcc::tp::{BatchKv, EngineOptions, TpEngine};

fn main() -> anyhow::Result<()> {
    let root = common::artifacts_root()?;

    // ---- Fig. 1 trace: one prefill with compressed collectives ----
    println!("== per-layer stage/communication trace (Fig. 1b) ==");
    let rt = Runtime::load(&root)?;
    let weights = Weights::load(&root.join("weights/micro"))?;
    let mut eng = TpEngine::new(
        rt,
        &weights,
        EngineOptions::new("micro", 2)
            .with_compress("fp4_e2m1_b32_e8m0")
            .with_profile("l4"),
    )?;
    let prompt_tokens: Vec<i32> =
        " = Thornbury = \n\n".bytes().take(16).map(|b| b as i32).collect();
    let mut padded = vec![0i32; 16];
    padded[..prompt_tokens.len()].copy_from_slice(&prompt_tokens);
    let mut kv = BatchKv::new(&eng.cfg.clone(), 2, 1);
    let (_logits, t) = eng.prefill(&padded, 1, 16, &[0], Some(&mut kv))?;
    println!(
        "prefill: compute {:.2}ms | link {:.3}ms | codec {:.3}ms | wire {} B (raw {} B, {:.2}x smaller)",
        t.compute_s * 1e3,
        t.link_s * 1e3,
        t.codec_s * 1e3,
        t.wire_bytes,
        t.raw_bytes,
        t.raw_bytes as f64 / t.wire_bytes as f64
    );
    println!(
        "collectives: 2 per layer x {} layers, each = quantize -> all-gather -> dequant+reduce",
        eng.cfg.n_layers
    );
    println!("effective bits: {:.2} (fp16 baseline: 16)\n", eng.effective_bits(192));

    // ---- generation through the coordinator ----
    println!("== generation (greedy, TP=2, compressed collectives) ==");
    let (handle, join) = spawn(
        move || {
            let rt = Runtime::load(&common::artifacts_root()?)?;
            let weights = Weights::load(&common::artifacts_root()?.join("weights/micro"))?;
            TpEngine::new(
                rt,
                &weights,
                EngineOptions::new("micro", 2).with_compress("fp4_e2m1_b32_e8m0"),
            )
        },
        CoordinatorOptions::default(),
    )?;
    for prompt in [" = Kestrel Holloway = \n\n", "The railway reached "] {
        let resp = handle.generate(GenRequest {
            prompt: prompt.to_string(),
            max_new_tokens: 64,
            greedy: true,
            stop_token: -1,
        })?;
        println!("prompt : {prompt:?}");
        println!("output : {:?}", resp.text);
        println!(
            "ttft {:.3}s | e2e {:.3}s | tpot {:.1}ms | virtual prefill {:.4}s\n",
            resp.ttft_s,
            resp.e2e_s,
            resp.tpot_s * 1e3,
            resp.virtual_prefill_s
        );
    }
    handle.shutdown();
    drop(handle);
    join.join().unwrap()?;
    println!("quickstart OK");
    Ok(())
}
