//! Perplexity sweep driver — regenerates paper Tables 1, 2 and 5.
//!
//!     cargo run --release --example ppl_sweep -- --table 1 [--tokens 4096]

use tpcc::tables::{common, table1, table2, table5};
use tpcc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let table = args.get_usize("table", 1);
    let tokens = args.get_usize("tokens", common::eval_tokens(4096));
    match table {
        1 => {
            let t = table1::run(tokens)?;
            table1::print(&t);
        }
        2 => {
            let rows = table2::run(tokens)?;
            table2::print(&rows);
        }
        5 => {
            let rows = table5::run(tokens)?;
            table5::print(&rows);
        }
        _ => anyhow::bail!("--table must be 1, 2 or 5"),
    }
    Ok(())
}
