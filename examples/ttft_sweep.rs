//! TTFT profiling driver — regenerates paper Table 3 (analytic paper-
//! scale deployments + live CPU-PJRT runs) and, with `--crossover`, the
//! §5.2/§6 claim that compression stops paying off once the interconnect
//! is fast enough: sweeps link bandwidth and prints the speedup curve.
//!
//!     cargo run --release --example ttft_sweep -- [--crossover] [--reps 5]

use tpcc::interconnect::{HwProfile, LinkModel};
use tpcc::model::perf_model::{Scenario, LLAMA2_70B};
use tpcc::mxfmt::baselines::Fp16;
use tpcc::mxfmt::{MxCodec, MxScheme};
use tpcc::tables::table3;
use tpcc::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();

    let rows = table3::run_analytic();
    table3::print(&rows, "analytic, paper-scale");

    if args.has("crossover") {
        println!("\nCrossover sweep — Llama-2 70B, TP=8, 2x128, FP4 E2M1/b32:");
        println!("{:>14} {:>12} {:>12} {:>9}", "link GB/s", "uncomp TTFT", "comp TTFT", "speedup");
        println!("{}", "-".repeat(52));
        let mx = MxCodec::new(MxScheme::parse(table3::PAPER_SCHEME).unwrap());
        let base = *HwProfile::by_name("l4").unwrap();
        for gbps in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0] {
            let mut prof = base;
            prof.link = LinkModel { alpha_s: prof.link.alpha_s, beta_bytes_per_s: gbps * 1e9 };
            // leak: benches are short-lived; HwProfile is Copy but Scenario
            // wants &'static — use Box::leak for the sweep points.
            let prof: &'static HwProfile = Box::leak(Box::new(prof));
            let sc = Scenario { model: LLAMA2_70B, profile: prof, tp: 8, batch: 2, seq: 128 };
            let unc = sc.ttft(&Fp16).total();
            let cmp = sc.ttft(&mx).total();
            println!("{:>14.0} {:>11.3}s {:>11.3}s {:>8.2}x", gbps, unc, cmp, unc / cmp);
        }
        println!("(speedup > 1 only while the link is slow: the paper's §6 limitation)");
    }

    // live section: micro model, bucket 8x128, l4 + a100 + cpu profiles
    let reps = args.get_usize("reps", 5);
    let mut live = Vec::new();
    for profile in ["l4", "a100"] {
        live.push(table3::run_live(profile, 2, 8, 128, reps, true)?);
    }
    table3::print(&live, "live micro model on CPU PJRT, virtual interconnect");
    Ok(())
}
