"""Model family + shape-bucket configuration shared by train/aot/export.

Three byte-level Llama-architecture models stand in for the paper's
Llama-3.1 / Gemma-2 / Mistral families (DESIGN.md substitution table).
Dims are chosen so that heads and FFN split evenly across every TP
degree we export (1, 2, 4, 8) and the model dim is a multiple of every
MX block size (8, 16, 32).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    head_dim: int
    d_ff: int
    max_seq: int = 320  # KV-cache capacity
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def params(self) -> int:
        d, h, hd, f = self.d_model, self.n_heads, self.head_dim, self.d_ff
        per_layer = d * h * hd * 3 + h * hd * d + 3 * d * f + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d

    def shard_heads(self, tp: int) -> int:
        assert self.n_heads % tp == 0, (self.name, tp)
        return self.n_heads // tp

    def shard_ff(self, tp: int) -> int:
        assert self.d_ff % tp == 0, (self.name, tp)
        return self.d_ff // tp


MODELS = {
    # name                vocab  d    L  H  hd   ff
    "nano": ModelConfig("nano", 256, 128, 2, 8, 16, 384),
    "micro": ModelConfig("micro", 256, 192, 3, 8, 24, 512),
    "small": ModelConfig("small", 256, 256, 3, 8, 32, 704),
}

TP_DEGREES = (1, 2, 4, 8)

# Shape buckets exported to HLO (static PJRT shapes). S=1 is the decode
# bucket; the rest serve prefill. The scheduler pads to the next bucket.
SEQ_BUCKETS = (1, 16, 64, 128, 256)
BATCH_BUCKETS = (1, 8)

# Schemes that also get *fused* quantize / dequant+reduce HLO executables
# (the full sweep runs through the bit-exact rust codec instead).
FUSED_SCHEMES = ("fp4_e2m1_b32_e8m0", "fp5_e2m2_b32_e8m0")

# Training hyper-parameters (build-time; one-core CPU budget).
TRAIN = {
    "nano": dict(steps=240, batch=8, seq=128, lr=3e-3),
    "micro": dict(steps=200, batch=8, seq=128, lr=2e-3),
    "small": dict(steps=160, batch=8, seq=128, lr=2e-3),
}
