"""L2: Llama-architecture model as explicit tensor-parallel worker stages.

The model is written twice, on purpose:

  * ``full_forward`` -- the monolithic reference used for training and as
    the TP-equivalence oracle in tests (plain jnp, differentiable).
  * the ``*_stage`` functions -- the per-worker shard programs that get
    AOT-lowered to HLO and executed by the rust coordinator. Each stage
    ends exactly where the paper's communication happens: the output of a
    *row-parallel* linear layer is a partial sum that must be all-gathered
    across the TP group and reduced (Fig. 1a). The compressed variants
    fuse the Pallas MX quantizer into the producing stage and the
    dequantize+reduce into the consuming side (Fig. 1b).

Stages call the L1 Pallas kernels (matmul / rmsnorm / mx) so the lowered
HLO exercises the same code the kernel tests verify.

TP layout (Megatron-style):
  attn:  wq/wk/wv column-parallel  [d, (H/n)*hd]  (heads split)
         wo      row-parallel      [(H/n)*hd, d]  -> partial out
  mlp :  w_gate/w_up column-parallel [d, f/n]
         w_down  row-parallel        [f/n, d]     -> partial out
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import matmul as pk_matmul
from .kernels import mx as pk_mx
from .kernels import rmsnorm as pk_rmsnorm
from .kernels.formats import MxScheme


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Flat name->array param dict (names match the npy export layout)."""
    d, hd, nh, f, v = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.d_ff, cfg.vocab
    qkv_dim = nh * hd
    keys = jax.random.split(key, 4 + cfg.n_layers * 9)
    p: Dict[str, jnp.ndarray] = {}

    def norm_init(k, fan_in, shape):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(jnp.float32)

    p["embed"] = norm_init(keys[0], 1.0, (v, d)) * 0.5
    p["final_norm"] = jnp.ones((d,), jnp.float32)
    p["lm_head"] = norm_init(keys[1], d, (d, v))
    ki = 4
    for l in range(cfg.n_layers):
        p[f"l{l}.attn_norm"] = jnp.ones((d,), jnp.float32)
        p[f"l{l}.wq"] = norm_init(keys[ki + 0], d, (d, qkv_dim))
        p[f"l{l}.wk"] = norm_init(keys[ki + 1], d, (d, qkv_dim))
        p[f"l{l}.wv"] = norm_init(keys[ki + 2], d, (d, qkv_dim))
        p[f"l{l}.wo"] = norm_init(keys[ki + 3], qkv_dim, (qkv_dim, d))
        p[f"l{l}.mlp_norm"] = jnp.ones((d,), jnp.float32)
        p[f"l{l}.w_gate"] = norm_init(keys[ki + 4], d, (d, f))
        p[f"l{l}.w_up"] = norm_init(keys[ki + 5], d, (d, f))
        p[f"l{l}.w_down"] = norm_init(keys[ki + 6], f, (f, d))
        ki += 9
    return p


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def rope_angles(cfg: ModelConfig, positions: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given positions: [S, hd/2]."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: [B, H, S, hd]; rotate pairs (even, odd) halves."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _attention(q, k, v, q_pos, kv_len):
    """q: [B,H,S,hd], k/v: [B,H,T,hd]; causal vs absolute kv positions.

    q_pos: i32[B, S] absolute position of each query token;
    kv_len: i32[B] number of valid cache slots per sequence.
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    t = k.shape[2]
    kv_pos = jnp.arange(t)  # [T]
    causal = kv_pos[None, None, :] <= q_pos[:, :, None]  # [B, S, T]
    valid = kv_pos[None, :] < kv_len[:, None]  # [B, T]
    mask = causal & valid[:, None, :]
    logits = jnp.where(mask[:, None], logits, jnp.float32(-1e30))
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v)


# --------------------------------------------------------------------------
# monolithic reference forward (training / oracle)
# --------------------------------------------------------------------------

def full_forward(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens i32[B, S] -> logits f32[B, S, V]; pure jnp (differentiable)."""
    b, s = tokens.shape
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    x = p["embed"][tokens]
    pos = jnp.arange(s)
    cos, sin = rope_angles(cfg, pos)

    def rms(x, g):
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + cfg.eps) * g

    for l in range(cfg.n_layers):
        h = rms(x, p[f"l{l}.attn_norm"])
        q = (h @ p[f"l{l}.wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        k = (h @ p[f"l{l}.wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        v = (h @ p[f"l{l}.wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        qp = jnp.broadcast_to(pos[None, :], (b, s))
        o = _attention(q, k, v, qp, jnp.full((b,), s, jnp.int32))
        o = o.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
        x = x + o @ p[f"l{l}.wo"]
        h = rms(x, p[f"l{l}.mlp_norm"])
        g = jax.nn.silu(h @ p[f"l{l}.w_gate"]) * (h @ p[f"l{l}.w_up"])
        x = x + g @ p[f"l{l}.w_down"]

    x = rms(x, p["final_norm"])
    return x @ p["lm_head"]


# --------------------------------------------------------------------------
# TP worker stages (AOT-exported; Pallas kernels inside)
# --------------------------------------------------------------------------

def embed_stage(tokens: jnp.ndarray, embed: jnp.ndarray) -> jnp.ndarray:
    """tokens i32[B,S], embed f32[V,D] -> x f32[B,S,D] (replicated)."""
    return embed[tokens]


def _qkv_rope(cfg: ModelConfig, tp: int, x, norm_w, wq, wk, wv, pos):
    """Shared front half: norm -> QKV projections -> RoPE.

    Returns q, k, v as [B, Hn, S, hd] plus q_pos [B, S]. pos is a
    *per-sequence* i32[B] vector so the continuous batcher can mix
    sequences of different lengths in one batch.
    """
    b, s, _ = x.shape
    hn = cfg.shard_heads(tp)
    hd = cfg.head_dim

    h = pk_rmsnorm.rmsnorm(x, norm_w, cfg.eps)
    q = pk_matmul.matmul_flat(h, wq).reshape(b, s, hn, hd).transpose(0, 2, 1, 3)
    k = pk_matmul.matmul_flat(h, wk).reshape(b, s, hn, hd).transpose(0, 2, 1, 3)
    v = pk_matmul.matmul_flat(h, wv).reshape(b, s, hn, hd).transpose(0, 2, 1, 3)

    q_pos = pos[:, None] + jnp.arange(s)[None, :]  # [B, S]
    half = hd // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = q_pos.astype(jnp.float32)[..., None] * freqs  # [B, S, hd/2]
    cos, sin = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]  # [B, 1, S, hd/2]

    def rot(t):
        t1, t2 = jnp.split(t, 2, axis=-1)
        return jnp.concatenate([t1 * cos - t2 * sin, t1 * sin + t2 * cos], axis=-1)

    return rot(q), rot(k), v, q_pos


def attn_prefill_stage(
    cfg: ModelConfig,
    tp: int,
    x: jnp.ndarray,      # f32[B, S, D] (replicated input)
    norm_w: jnp.ndarray, # f32[D]
    wq: jnp.ndarray,     # f32[D, Hn*hd]  column shard
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,     # f32[Hn*hd, D]  row shard
    pos: jnp.ndarray,    # i32[B]
):
    """Prefill attention (no KV history): -> (partial, k, v).

    k/v are the [B, Hn, S, hd] slices for the rust-side cache (the
    authoritative cache lives in the coordinator, so the TTFT-critical
    prefill path moves NO cache-sized tensors through PJRT).
    """
    b, s, _ = x.shape
    hn = cfg.shard_heads(tp)
    q, k, v, q_pos = _qkv_rope(cfg, tp, x, norm_w, wq, wk, wv, pos)
    o = _attention(q, k, v, q_pos, pos + s)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hn * cfg.head_dim)
    partial = pk_matmul.matmul_flat(o, wo)  # row-parallel partial sum
    return partial, k, v


def attn_stage(
    cfg: ModelConfig,
    tp: int,
    x: jnp.ndarray,        # f32[B, S, D]
    norm_w: jnp.ndarray,
    wq: jnp.ndarray,
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,
    k_cache: jnp.ndarray,  # f32[B, Hn, T, hd] -- history only (input)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,      # i32[B]
):
    """Decode attention with KV history: -> (partial, k_new, v_new).

    k_new/v_new are only the [B, Hn, S, hd] slices for the new tokens;
    the coordinator mirrors the cache update on its side (the full cache
    is never an HLO *output*, which keeps per-step PJRT traffic small).
    """
    b, s, _ = x.shape
    hn = cfg.shard_heads(tp)
    q, k, v, q_pos = _qkv_rope(cfg, tp, x, norm_w, wq, wk, wv, pos)

    # write new k/v into each sequence's cache slice at [pos_b, pos_b+s)
    def upd(cache, new, p):
        return jax.lax.dynamic_update_slice(cache, new, (0, p, 0))

    k_full = jax.vmap(upd)(k_cache, k, pos)
    v_full = jax.vmap(upd)(v_cache, v, pos)

    o = _attention(q, k_full, v_full, q_pos, pos + s)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hn * cfg.head_dim)
    partial = pk_matmul.matmul_flat(o, wo)
    return partial, k, v


def mlp_stage(
    cfg: ModelConfig,
    tp: int,
    x: jnp.ndarray,       # f32[B, S, D]
    norm_w: jnp.ndarray,  # f32[D]
    w_gate: jnp.ndarray,  # f32[D, Fn] column shard
    w_up: jnp.ndarray,    # f32[D, Fn]
    w_down: jnp.ndarray,  # f32[Fn, D] row shard
) -> jnp.ndarray:
    """One worker's SwiGLU MLP -> partial f32[B,S,D] (row-parallel)."""
    h = pk_rmsnorm.rmsnorm(x, norm_w, cfg.eps)
    g = jax.nn.silu(pk_matmul.matmul_flat(h, w_gate)) * pk_matmul.matmul_flat(h, w_up)
    return pk_matmul.matmul_flat(g, w_down)


def final_stage(cfg: ModelConfig, x: jnp.ndarray, norm_w: jnp.ndarray, lm_head: jnp.ndarray) -> jnp.ndarray:
    """Final RMSNorm + LM head -> logits f32[B, S, V] (leader only)."""
    h = pk_rmsnorm.rmsnorm(x, norm_w, cfg.eps)
    return pk_matmul.matmul_flat(h, lm_head)


# --- communication ops (exported as standalone executables) ----------------

def reduce_add(x: jnp.ndarray, partials: jnp.ndarray) -> jnp.ndarray:
    """Uncompressed path: x + sum_n partials[n] (residual + TP reduce)."""
    return x + jnp.sum(partials, axis=0)


def quantize_op(x: jnp.ndarray, s: MxScheme):
    """Compress one worker's partial before the all-gather (Fig 1b 'encode')."""
    return pk_mx.mx_quantize(x, s)


def dequant_reduce_add(x: jnp.ndarray, codes: jnp.ndarray, scales: jnp.ndarray, s: MxScheme):
    """Decompress N gathered shards, reduce, add residual (Fig 1b 'decode+sum')."""
    return x + pk_mx.mx_dequant_reduce(codes, scales, s)


# --------------------------------------------------------------------------
# python-side TP-sharded forward (oracle for rust; used in tests)
# --------------------------------------------------------------------------

def shard_params(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tp: int, rank: int) -> Dict[str, jnp.ndarray]:
    """Slice the full param dict into worker `rank`'s TP shard."""
    hn, hd, fn = cfg.shard_heads(tp), cfg.head_dim, cfg.shard_ff(tp)
    qa, qb = rank * hn * hd, (rank + 1) * hn * hd
    fa, fb = rank * fn, (rank + 1) * fn
    sp: Dict[str, jnp.ndarray] = {
        "embed": p["embed"],
        "final_norm": p["final_norm"],
        "lm_head": p["lm_head"],
    }
    for l in range(cfg.n_layers):
        sp[f"l{l}.attn_norm"] = p[f"l{l}.attn_norm"]
        sp[f"l{l}.wq"] = p[f"l{l}.wq"][:, qa:qb]
        sp[f"l{l}.wk"] = p[f"l{l}.wk"][:, qa:qb]
        sp[f"l{l}.wv"] = p[f"l{l}.wv"][:, qa:qb]
        sp[f"l{l}.wo"] = p[f"l{l}.wo"][qa:qb, :]
        sp[f"l{l}.mlp_norm"] = p[f"l{l}.mlp_norm"]
        sp[f"l{l}.w_gate"] = p[f"l{l}.w_gate"][:, fa:fb]
        sp[f"l{l}.w_up"] = p[f"l{l}.w_up"][:, fa:fb]
        sp[f"l{l}.w_down"] = p[f"l{l}.w_down"][fa:fb, :]
    return sp


def tp_forward(
    cfg: ModelConfig,
    p: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    tp: int,
    scheme: MxScheme | None = None,
) -> jnp.ndarray:
    """Full forward assembled from the worker stages, with (optionally
    compressed) reduce at every row-parallel boundary. This is the oracle
    the rust coordinator must match (tests/test_tp_equivalence.py and the
    rust integration tests both pin against it)."""
    b, s = tokens.shape
    shards = [shard_params(cfg, p, tp, r) for r in range(tp)]
    x = embed_stage(tokens, p["embed"])
    pos = jnp.zeros((b,), jnp.int32)

    def comm(x, partials: List[jnp.ndarray]) -> jnp.ndarray:
        stacked = jnp.stack(partials)
        if scheme is None:
            return reduce_add(x, stacked)
        cs = [quantize_op(pt, scheme) for pt in partials]
        codes = jnp.stack([c for c, _ in cs])
        scales = jnp.stack([sc for _, sc in cs])
        return dequant_reduce_add(x, codes, scales, scheme)

    for l in range(cfg.n_layers):
        parts = []
        for r in range(tp):
            pa, _, _ = attn_prefill_stage(
                cfg, tp, x,
                shards[r][f"l{l}.attn_norm"], shards[r][f"l{l}.wq"],
                shards[r][f"l{l}.wk"], shards[r][f"l{l}.wv"], shards[r][f"l{l}.wo"],
                pos,
            )
            parts.append(pa)
        x = comm(x, parts)
        parts = [
            mlp_stage(
                cfg, tp, x,
                shards[r][f"l{l}.mlp_norm"], shards[r][f"l{l}.w_gate"],
                shards[r][f"l{l}.w_up"], shards[r][f"l{l}.w_down"],
            )
            for r in range(tp)
        ]
        x = comm(x, parts)

    return final_stage(cfg, x, p["final_norm"], p["lm_head"])
