"""Build-time pretraining of the three byte-level LMs.

Runs ONCE under ``make artifacts`` (skipped when weights already exist).
Trains each ModelConfig on the synthetic corpus with Adam + cosine decay,
logs the loss curve to artifacts/weights/<model>/train_log.json, and
saves every parameter as a .npy file the rust loader can parse.

This is tooling, not the request path: the serving system never imports
python (DESIGN.md three-layer contract). Training uses the monolithic
jnp ``full_forward`` for speed; the exported *inference* stages run the
Pallas kernels and are pinned against this model by the equivalence
tests.
"""

from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .configs import MODELS, TRAIN, ModelConfig
from .model import full_forward, init_params


def loss_fn(cfg: ModelConfig, p, tokens):
    """Next-byte cross-entropy over [B, S+1] token windows."""
    logits = full_forward(cfg, p, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def adam_init(p):
    zeros = jax.tree.map(jnp.zeros_like, p)
    return zeros, jax.tree.map(jnp.zeros_like, p)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2, 3))
def train_step(cfg: ModelConfig, p, m, v, tokens, step, lr_base, total_steps):
    loss, grads = jax.value_and_grad(lambda q: loss_fn(cfg, q, tokens))(p)
    b1, b2, eps = 0.9, 0.95, 1e-8
    t = step + 1
    # cosine decay with short warmup
    warm = jnp.minimum(1.0, t / 20.0)
    lr = lr_base * warm * 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(t / total_steps, 1.0)))
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
    p = jax.tree.map(lambda w, a, b: w - lr * a / (jnp.sqrt(b) + eps), p, mh, vh)
    return p, m, v, loss


def batches(data: np.ndarray, batch: int, seq: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(data) - (seq + 1)
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([data[i : i + seq + 1] for i in idx]).astype(np.int32)


def train_model(cfg: ModelConfig, data: np.ndarray, out_dir: str) -> dict:
    hp = TRAIN[cfg.name]
    key = jax.random.PRNGKey(42)
    p = init_params(cfg, key)
    m, v = adam_init(p)
    it = batches(data, hp["batch"], hp["seq"], seed=7)
    log = {"model": cfg.name, "params": cfg.params, "steps": [], "loss": []}
    t0 = time.time()
    for step in range(hp["steps"]):
        tokens = jnp.asarray(next(it))
        p, m, v, loss = train_step(cfg, p, m, v, tokens, step, hp["lr"], hp["steps"])
        if step % 10 == 0 or step == hp["steps"] - 1:
            lv = float(loss)
            log["steps"].append(step)
            log["loss"].append(round(lv, 4))
            print(f"[{cfg.name}] step {step:4d} loss {lv:.4f} ({time.time()-t0:.0f}s)", flush=True)
    log["wall_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    for name, arr in p.items():
        np.save(os.path.join(out_dir, name.replace("/", "_") + ".npy"), np.asarray(arr))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    return log


def main(out_root: str = "../artifacts/weights"):
    train_text, test_text = corpus.train_test()
    os.makedirs(out_root, exist_ok=True)
    with open(os.path.join(out_root, "corpus_train.txt"), "w") as f:
        f.write(train_text)
    with open(os.path.join(out_root, "corpus_test.txt"), "w") as f:
        f.write(test_text)
    data = np.frombuffer(train_text.encode("utf-8"), dtype=np.uint8)
    for name, cfg in MODELS.items():
        out_dir = os.path.join(out_root, name)
        if os.path.exists(os.path.join(out_dir, "train_log.json")):
            print(f"[{name}] weights exist, skipping")
            continue
        train_model(cfg, data, out_dir)


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "../artifacts/weights")
