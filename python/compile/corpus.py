"""Deterministic synthetic wikitext-like corpus.

Substitute for Wikitext-2 (offline image has no datasets; see DESIGN.md
substitution table). A seeded generator expands encyclopedic sentence
templates over invented entity tables, yielding text with natural-language
statistics (heading structure, varied sentence lengths, numbers, named
entities, punctuation) -- enough for the byte-level LMs to reach a
non-trivial perplexity so that quantization damage is measurable and
ordered the way the paper's Table 1/5 axes order it.

The split mirrors the paper's protocol: a *train* portion (we evaluate
scheme search on a 10% slice of it, like the paper) and a held-out
*test* portion for the final Table 2/4 numbers.
"""

from __future__ import annotations

import random

FIRST = [
    "Aldery", "Brimwick", "Caldens", "Dorvale", "Elmira", "Fenwick", "Garlan",
    "Hartwell", "Iverness", "Jorvik", "Kestrel", "Lorwyn", "Marlow", "Norvell",
    "Ostrand", "Pellam", "Quardon", "Rivenhall", "Selwyn", "Tormund",
]
SURN = [
    "Ashworth", "Blackwood", "Carmody", "Draven", "Ellsworth", "Fairburn",
    "Greaves", "Holloway", "Ingram", "Jessop", "Kirkland", "Lockhart",
    "Mercer", "Northam", "Ormsby", "Pemberton", "Quill", "Ravenscroft",
    "Standish", "Thorne",
]
PLACES = [
    "Avonmere", "Bexley Cross", "Carrow Fen", "Dunmore", "Eastvale",
    "Farrowgate", "Glenholm", "Harrowfield", "Istermouth", "Juneberry Hollow",
    "Kilnmarsh", "Larkspur", "Mossbridge", "Netherby", "Oakhaven",
    "Pellbrook", "Quarry Hill", "Redmarch", "Silverstrand", "Thornbury",
]
FIELDS = [
    "astronomy", "botany", "cartography", "geology", "linguistics",
    "mathematics", "medicine", "meteorology", "music theory", "philosophy",
    "physics", "zoology", "archaeology", "chemistry", "economics",
]
INSTITUTIONS = [
    "the Royal Academy", "the National Institute", "the Provincial College",
    "the Observatory of %s" % PLACES[3], "the Museum of Natural History",
    "the Society of Letters", "the Polytechnic School",
]
RIVERS = ["Arlen", "Brev", "Calder", "Dunwash", "Esk", "Fallow", "Grenn", "Hollis"]
ADJ = [
    "notable", "prominent", "influential", "celebrated", "controversial",
    "prolific", "renowned", "early", "pioneering", "obscure",
]
WORKS = [
    "treatise", "monograph", "survey", "compendium", "atlas", "catalogue",
    "lexicon", "chronicle", "commentary", "almanac",
]

BIO_TEMPLATES = [
    "{first} {surn} ( {by} – {dy} ) was a {adj} {field} scholar from {place} . "
    "{surn} studied at {inst} , where {pron} published {pron_pos} first {work} in {wy} . ",
    "{first} {surn} was born in {place} in {by} , the {ord} child of a {prof} . "
    "After moving to {place2} in {my} , {pron} devoted {pron_pos} career to {field} . ",
    "The {work} of {first} {surn} , completed in {wy} , remains a standard reference in {field} . "
    "It catalogued {num} specimens collected along the river {river} . ",
    "In {wy} , {surn} was elected to {inst} , an honour rarely extended to scholars of {field} at the time . ",
    "{surn} 's later work turned to {field2} , producing a {adj} {work} that ran to {num} pages . ",
]

PLACE_TEMPLATES = [
    "{place} is a market town on the river {river} , first recorded in {fy} . "
    "The town grew around a {prof2} 's bridge and reached a population of {pop} by {cy} . ",
    "The parish church of {place} , rebuilt in {fy} , is a {adj} example of regional masonry . "
    "Its tower stands {num} feet above the churchyard . ",
    "{place} lies {num} miles from {place2} along the old {field} road . "
    "A weekly market has been held there since {fy} . ",
    "During the floods of {cy} , the {river} rose {snum} feet at {place} , "
    "damaging {num} dwellings and the lower mill . ",
    "The railway reached {place} in {cy} , linking it to {place2} and ending the era of the {prof2} coaches . ",
]

EVENT_TEMPLATES = [
    "The {ord} Congress of {field} convened at {place} in {cy} , drawing {num} delegates . "
    "Its proceedings , edited by {surn} , filled three volumes . ",
    "A {adj} dispute between {surn} and {surn2} over the classification of {field} "
    "occupied the journals from {cy} to {cy2} . ",
    "The {inst} prize of {cy} was awarded jointly to {surn} and {surn2} "
    "for their {work} on the {river} valley . ",
]

PROFESSIONS = ["weaver", "printer", "surveyor", "apothecary", "clockmaker", "miller", "engraver"]
ORDINALS = ["first", "second", "third", "fourth", "fifth", "sixth", "seventh"]


def _sentence(rng: random.Random) -> str:
    kind = rng.random()
    if kind < 0.45:
        t = rng.choice(BIO_TEMPLATES)
    elif kind < 0.8:
        t = rng.choice(PLACE_TEMPLATES)
    else:
        t = rng.choice(EVENT_TEMPLATES)
    by = rng.randint(1680, 1890)
    pron = rng.choice(["he", "she"])
    return t.format(
        first=rng.choice(FIRST),
        surn=rng.choice(SURN),
        surn2=rng.choice(SURN),
        place=rng.choice(PLACES),
        place2=rng.choice(PLACES),
        field=rng.choice(FIELDS),
        field2=rng.choice(FIELDS),
        inst=rng.choice(INSTITUTIONS),
        river=rng.choice(RIVERS),
        adj=rng.choice(ADJ),
        work=rng.choice(WORKS),
        prof=rng.choice(PROFESSIONS),
        prof2=rng.choice(PROFESSIONS),
        ord=rng.choice(ORDINALS),
        pron=pron,
        pron_pos="his" if pron == "he" else "her",
        by=by,
        dy=by + rng.randint(40, 80),
        wy=by + rng.randint(20, 40),
        my=by + rng.randint(15, 30),
        fy=rng.randint(1100, 1600),
        cy=rng.randint(1700, 1900),
        cy2=rng.randint(1700, 1900),
        num=rng.randint(2, 900),
        snum=rng.randint(2, 30),
        pop=rng.randint(300, 20000),
    )


def _article(rng: random.Random) -> str:
    title = f"{rng.choice(FIRST)} {rng.choice(SURN)}" if rng.random() < 0.5 else rng.choice(PLACES)
    lines = [f" = {title} = \n\n"]
    for _ in range(rng.randint(2, 4)):
        if rng.random() < 0.4:
            lines.append(f" = = {rng.choice(FIELDS).title()} = = \n\n")
        para = " ".join(_sentence(rng) for _ in range(rng.randint(2, 5)))
        lines.append(para + "\n\n")
    return "".join(lines)


def generate(n_bytes: int, seed: int = 0) -> str:
    """Generate at least n_bytes of corpus text, deterministically."""
    rng = random.Random(seed)
    parts: list[str] = []
    total = 0
    while total < n_bytes:
        a = _article(rng)
        parts.append(a)
        total += len(a)
    return "".join(parts)


def train_test(train_bytes: int = 400_000, test_bytes: int = 48_000, seed: int = 1234):
    """Disjoint train/test streams (different seeds => different articles)."""
    return generate(train_bytes, seed), generate(test_bytes, seed + 1)


if __name__ == "__main__":
    tr, te = train_test()
    print(tr[:600])
    print(f"train={len(tr)} test={len(te)} bytes")
