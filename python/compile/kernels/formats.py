"""MX (OCP Microscaling) format specifications.

Shared by the pure-jnp reference (ref.py), the Pallas kernels (mx.py), the
AOT exporter (golden vectors for the rust codec cross-check), and tests.

An MX scheme = (element format, scale format, block size):

  * element format -- tiny float ``ExMy`` (1 sign, x exponent, y mantissa
    bits, no inf/nan, subnormals supported) or sign-magnitude ``INTk``.
  * scale format   -- ``EdM0``: a power-of-two scale stored as a d-bit
    biased exponent (exponent-only float, M=0).
  * block size     -- number of consecutive values sharing one scale.

Effective bits (paper Table 1/4.2):  elem_bits + scale_bits / block_size.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ElemFormat:
    """Element (value) data type of an MX block."""

    name: str
    is_float: bool
    ebits: int  # exponent bits (float) -- 0 for INT
    mbits: int  # mantissa bits (float) / magnitude bits (INT, excl. sign)

    @property
    def bits(self) -> int:
        """Total storage bits per element, including the sign bit."""
        return 1 + self.ebits + self.mbits

    @property
    def bias(self) -> int:
        assert self.is_float
        return (1 << (self.ebits - 1)) - 1

    @property
    def emax(self) -> int:
        """Largest unbiased exponent (MX spec: no inf/nan, full code space).

        For ExMy this is 2^(ebits-1); for INTk we define emax as
        floor(log2(qmax)) = mbits - 1 + (qmax == 2^mbits - 1 ... ) -- the
        exponent of the largest representable magnitude, used to map the
        block amax onto the top of the code range.
        """
        if self.is_float:
            return 1 << (self.ebits - 1)
        # INTk: largest magnitude is 2^mbits - 1, floor(log2) = mbits - 1
        return self.mbits - 1

    @property
    def emin(self) -> int:
        """Smallest *normal* unbiased exponent (floats only)."""
        assert self.is_float
        return 1 - self.bias

    @property
    def max_value(self) -> float:
        """Largest representable magnitude."""
        if self.is_float:
            # top exponent, all-ones mantissa (no inf/nan in MX elem types)
            return float(2.0**self.emax * (2.0 - 2.0**-self.mbits))
        return float((1 << self.mbits) - 1)

    @property
    def int_qmax(self) -> int:
        assert not self.is_float
        return (1 << self.mbits) - 1


@dataclasses.dataclass(frozen=True)
class ScaleFormat:
    """EdM0 power-of-two scale: a d-bit biased exponent."""

    ebits: int

    @property
    def name(self) -> str:
        return f"E{self.ebits}M0"

    @property
    def bits(self) -> int:
        return self.ebits

    @property
    def bias(self) -> int:
        return (1 << (self.ebits - 1)) - 1

    @property
    def emax(self) -> int:
        # Symmetric clamp range [-(2^(d-1)-1), +(2^(d-1)-1)]; for E8M0 this
        # matches the MX spec's [-127, 127] with 0xFF reserved for NaN.
        return (1 << (self.ebits - 1)) - 1

    @property
    def emin(self) -> int:
        return -self.emax


# --- the paper's element dtype zoo (Section 4.1) -------------------------
ELEM_FORMATS = {
    "fp5_e3m1": ElemFormat("fp5_e3m1", True, 3, 1),
    "fp5_e2m2": ElemFormat("fp5_e2m2", True, 2, 2),
    "fp5_e1m3": ElemFormat("fp5_e1m3", True, 1, 3),
    "fp4_e2m1": ElemFormat("fp4_e2m1", True, 2, 1),
    "fp4_e1m2": ElemFormat("fp4_e1m2", True, 1, 2),
    "fp3_e1m1": ElemFormat("fp3_e1m1", True, 1, 1),
    "int3": ElemFormat("int3", False, 0, 2),
    "int4": ElemFormat("int4", False, 0, 3),
    "int5": ElemFormat("int5", False, 0, 4),
}

SCALE_FORMATS = {f"e{d}m0": ScaleFormat(d) for d in (4, 5, 6, 7, 8)}

BLOCK_SIZES = (8, 16, 32)


@dataclasses.dataclass(frozen=True)
class MxScheme:
    """A complete MX quantization scheme."""

    elem: ElemFormat
    scale: ScaleFormat
    block: int

    @property
    def name(self) -> str:
        return f"{self.elem.name}_b{self.block}_{self.scale.name.lower()}"

    @property
    def effective_bits(self) -> float:
        return self.elem.bits + self.scale.bits / self.block

    @property
    def compression_ratio(self) -> float:
        """vs fp16 activations (the paper's uncompressed baseline)."""
        return 16.0 / self.effective_bits

    def wire_bytes(self, n_values: int) -> int:
        """Bit-packed wire size for n_values (must be block-aligned)."""
        assert n_values % self.block == 0
        nblocks = n_values // self.block
        bits = nblocks * (self.block * self.elem.bits + self.scale.bits)
        return (bits + 7) // 8


def scheme(elem: str, block: int, scale: str = "e8m0") -> MxScheme:
    return MxScheme(ELEM_FORMATS[elem], SCALE_FORMATS[scale], block)


# The paper's headline scheme for TTFT profiling (Table 3): FP4 E2M1,
# block 32, E8M0 scale -> 4.25 effective bits.
PAPER_TTFT_SCHEME = scheme("fp4_e2m1", 32, "e8m0")
