"""Blocked matmul Pallas kernel used by the TP linear layers.

MXU-shaped: 128x128 output tiles with a K-loop over 128-wide slabs.
The K axis is the innermost grid dimension and the output BlockSpec does
not map it, so the same output tile stays resident in VMEM across the
K-loop and serves as the accumulator (the classic Pallas matmul
pattern; on real TPUs the MXU consumes bf16 operands -- here operands
stay f32 because the CPU interpret path is our execution target, see
DESIGN.md #Hardware-Adaptation).

The row-parallel TP layers call this and hand the output tile straight
to the MX quantizer (mx.py) while it is still in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tile sizes; shrunk automatically for small operands.
TILE_M = 128
TILE_N = 128
TILE_K = 128


def _pick(tile: int, dim: int) -> int:
    t = min(tile, dim)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Grid (m, n, k): accumulate x[m,k] @ w[k,n] into the (m,n) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """f32[M, K] @ f32[K, N] -> f32[M, N] (2-D only; callers flatten)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    tm, tn, tk = _pick(TILE_M, m), _pick(TILE_N, n), _pick(TILE_K, k)
    nk = k // tk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // tm, n // tn, nk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_flat(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Matmul over the last axis of an arbitrarily-batched x."""
    lead = x.shape[:-1]
    out = matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(lead + (w.shape[-1],))
