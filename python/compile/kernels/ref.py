"""Pure-jnp bit-exact reference (oracle) for the MX quantization kernels.

Every operation here is chosen to be exactly reproducible in the rust
codec (rust/src/mxfmt/):

  * floor(log2(x)) is computed from the f32 bit pattern (biased exponent
    field), never via libm ``log2`` (whose last-ulp behaviour differs
    between XLA and rust libm).
  * powers of two are materialized by bit-assembling the f32 exponent
    field, so scaling/unscaling multiplications are exact.
  * mantissa rounding is round-to-nearest, ties-to-even (numpy/XLA
    ``round`` == rust ``f32::round_ties_even``).

The wire format produced by ``quantize_ref`` is (codes, scales):
  codes  -- uint8, one element code per value: sign<<(e+m) | exp<<m | mant
            for floats, sign<<m_bits | magnitude for INTs.
  scales -- uint8, the biased scale exponent per block (bias of the
            EdM0 format).
Bit-packing to the true wire width happens in the rust codec; effective
bits are accounted analytically everywhere else.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import ElemFormat, MxScheme, ScaleFormat


def _floor_log2(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(|x|)) for x > 0 via the f32 exponent field.

    For normal f32 this is exactly the unbiased exponent. Subnormal f32
    inputs (|x| < 2^-126) are mapped to -127 -- fine for activations,
    and mirrored exactly by the rust codec.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _exp2i(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e for integer e in [-126, 127], by assembling f32 bits."""
    e = jnp.clip(e, -126, 127)
    return jax.lax.bitcast_convert_type(((e + 127) << 23).astype(jnp.int32), jnp.float32)


# --------------------------------------------------------------------------
# scale selection
# --------------------------------------------------------------------------

def block_scale_exp(amax: jnp.ndarray, elem: ElemFormat, scale: ScaleFormat) -> jnp.ndarray:
    """Shared (unbiased) power-of-two exponent for a block given its amax.

    MX spec: shared_exp = floor(log2(amax)) - emax_elem, so the largest
    value in the block lands in the top binade of the element format.
    Clamped into the EdM0 representable range; amax == 0 maps to the
    smallest representable scale (codes will be all-zero anyway).
    """
    raw = _floor_log2(amax) - elem.emax
    raw = jnp.where(amax > 0, raw, scale.emin)
    return jnp.clip(raw, scale.emin, scale.emax)


# --------------------------------------------------------------------------
# element quantize / encode / decode
# --------------------------------------------------------------------------

def quantize_elem_float(v: jnp.ndarray, elem: ElemFormat) -> jnp.ndarray:
    """Round v (already divided by the block scale) onto the ExMy grid.

    Returns the exactly-representable f32 value (not the bit code).
    """
    sign = jnp.where(v < 0, -1.0, 1.0).astype(jnp.float32)
    a = jnp.abs(v.astype(jnp.float32))
    maxv = jnp.float32(elem.max_value)
    # exponent of the target binade; clamp to the normal/subnormal floor
    e = jnp.clip(_floor_log2(a), elem.emin, elem.emax)
    # quantization step in that binade: 2^(e - mbits)
    step = _exp2i(e - elem.mbits)
    q = jnp.round(a / step) * step  # ties-to-even; carry to next binade ok
    q = jnp.minimum(q, maxv)  # saturate (MX: no inf)
    q = jnp.where(a == 0, 0.0, q)
    return sign * q


def quantize_elem_int(v: jnp.ndarray, elem: ElemFormat) -> jnp.ndarray:
    """Round v onto the signed-magnitude INTk grid (integers)."""
    qmax = jnp.float32(elem.int_qmax)
    q = jnp.round(v.astype(jnp.float32))
    return jnp.clip(q, -qmax, qmax)


def encode_elem_float(q: jnp.ndarray, elem: ElemFormat) -> jnp.ndarray:
    """Bit-encode an exactly-representable ExMy value to its uint8 code."""
    sign = (q < 0).astype(jnp.int32)
    a = jnp.abs(q)
    e = _floor_log2(a)
    is_sub = (a == 0) | (e < elem.emin)
    # normal: exp_field = e + bias, mant = a/2^(e-M) - 2^M
    mant_n = jnp.round(a / _exp2i(e - elem.mbits)).astype(jnp.int32) - (1 << elem.mbits)
    exp_n = e + elem.bias
    # subnormal: exp_field = 0, mant = a / 2^(emin - M)
    mant_s = jnp.round(a / _exp2i(jnp.full_like(e, elem.emin - elem.mbits))).astype(jnp.int32)
    exp_f = jnp.where(is_sub, 0, exp_n)
    mant_f = jnp.where(is_sub, mant_s, mant_n)
    code = (sign << (elem.ebits + elem.mbits)) | (exp_f << elem.mbits) | mant_f
    return code.astype(jnp.uint8)


def decode_elem_float(code: jnp.ndarray, elem: ElemFormat) -> jnp.ndarray:
    code = code.astype(jnp.int32)
    sign = (code >> (elem.ebits + elem.mbits)) & 1
    exp_f = (code >> elem.mbits) & ((1 << elem.ebits) - 1)
    mant = code & ((1 << elem.mbits) - 1)
    # normal: (2^M + mant) * 2^(exp_f - bias - M); subnormal: mant * 2^(emin - M)
    mag_n = ((1 << elem.mbits) + mant).astype(jnp.float32) * _exp2i(exp_f - elem.bias - elem.mbits)
    mag_s = mant.astype(jnp.float32) * _exp2i(jnp.full_like(exp_f, elem.emin - elem.mbits))
    mag = jnp.where(exp_f == 0, mag_s, mag_n)
    return jnp.where(sign == 1, -mag, mag)


def encode_elem_int(q: jnp.ndarray, elem: ElemFormat) -> jnp.ndarray:
    sign = (q < 0).astype(jnp.int32)
    mag = jnp.abs(q).astype(jnp.int32)
    return ((sign << elem.mbits) | mag).astype(jnp.uint8)


def decode_elem_int(code: jnp.ndarray, elem: ElemFormat) -> jnp.ndarray:
    code = code.astype(jnp.int32)
    sign = (code >> elem.mbits) & 1
    mag = (code & ((1 << elem.mbits) - 1)).astype(jnp.float32)
    return jnp.where(sign == 1, -mag, mag)


# --------------------------------------------------------------------------
# full-tensor reference quantize / dequantize
# --------------------------------------------------------------------------

def _to_blocks(x: jnp.ndarray, block: int) -> jnp.ndarray:
    assert x.shape[-1] % block == 0, (x.shape, block)
    return x.reshape(x.shape[:-1] + (x.shape[-1] // block, block))


def quantize_ref(x: jnp.ndarray, s: MxScheme):
    """Reference MX quantize: x -> (codes uint8, scales uint8).

    codes has x's shape; scales has shape x.shape[:-1] + (C/block,).
    """
    xb = _to_blocks(x.astype(jnp.float32), s.block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    sexp = block_scale_exp(amax, s.elem, s.scale)
    inv = _exp2i(-sexp)[..., None]  # exact: scale is a power of two
    v = xb * inv
    if s.elem.is_float:
        q = quantize_elem_float(v, s.elem)
        codes = encode_elem_float(q, s.elem)
    else:
        q = quantize_elem_int(v, s.elem)
        codes = encode_elem_int(q, s.elem)
    scales = (sexp + s.scale.bias).astype(jnp.uint8)
    return codes.reshape(x.shape), scales


def dequantize_ref(codes: jnp.ndarray, scales: jnp.ndarray, s: MxScheme) -> jnp.ndarray:
    cb = _to_blocks(codes, s.block)
    if s.elem.is_float:
        v = decode_elem_float(cb, s.elem)
    else:
        v = decode_elem_int(cb, s.elem)
    sexp = scales.astype(jnp.int32) - s.scale.bias
    out = v * _exp2i(sexp)[..., None]
    return out.reshape(codes.shape).astype(jnp.float32)


def fake_quantize_ref(x: jnp.ndarray, s: MxScheme) -> jnp.ndarray:
    """quantize -> dequantize round trip (the error-injection view)."""
    codes, scales = quantize_ref(x, s)
    return dequantize_ref(codes, scales, s)


# --------------------------------------------------------------------------
# reference versions of the model kernels (oracles for pallas)
# --------------------------------------------------------------------------

def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def rmsnorm_ref(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def dequant_reduce_ref(codes: jnp.ndarray, scales: jnp.ndarray, s: MxScheme) -> jnp.ndarray:
    """Decompress N gathered worker shards and sum them (paper Fig 1b).

    codes: [N, ...], scales: [N, ...] -> sum over N of dequantized tensors.
    """
    return jnp.sum(jax.vmap(lambda c, sc: dequantize_ref(c, sc, s))(codes, scales), axis=0)
