"""Fused RMSNorm Pallas kernel.

One grid step normalizes a (ROW_TILE, D) tile: the mean-square
reduction, rsqrt and gain multiply all happen in one VMEM pass (vs three
HBM round-trips unfused). D is the lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128
EPS = 1e-5


def _row_tile(nrows: int) -> int:
    t = min(ROW_TILE, nrows)
    while nrows % t != 0:
        t -= 1
    return t


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g_ref[...]


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = EPS) -> jnp.ndarray:
    """f32[..., D] * rsqrt(mean(x^2)) * g -- Pallas-fused."""
    orig = x.shape
    d = orig[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    tile = _row_tile(rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), jnp.float32),
        interpret=True,
    )(x2, g)
    return out.reshape(orig)
