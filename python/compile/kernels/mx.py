"""Pallas kernels for MX block quantization of TP communication.

These are the paper's compute hot-spot: every row-parallel linear layer
output is quantized before the all-gather and dequantized+reduced after
it (Fig. 1b). The kernels are written TPU-style:

  * the block (last) axis is the lane axis; a grid step processes a
    ``(ROW_TILE, row_len)`` VMEM tile = ROW_TILE rows of blocks, so the
    per-block amax reduction and the scale broadcast stay inside one
    vreg-resident tile (8x128 vregs on TPU; no HBM round-trips),
  * all transcendentals are avoided -- scale selection is pure exponent
    bit manipulation (see ref.py), VPU-friendly,
  * quantize is intended to fuse directly after the row-parallel matmul
    tile (producer in VMEM), which is what makes compression nearly free
    on the compute side.

Run with interpret=True everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls (real-TPU lowering); interpret mode lowers to plain
HLO so the rust runtime can run the same artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .formats import MxScheme

# Rows of values processed per grid step. On TPU this would be tuned to
# the VMEM budget (a (128, C) f32 tile at C=1024 is 512 KB); interpret
# mode just needs it to divide the row count or be handled by the last
# partial tile (we require divisibility and pick tiles accordingly).
DEFAULT_ROW_TILE = 128


def _row_tile(nrows: int) -> int:
    t = min(DEFAULT_ROW_TILE, nrows)
    while nrows % t != 0:
        t -= 1
    return t


def _quantize_kernel(x_ref, codes_ref, scales_ref, *, s: MxScheme):
    """One grid step: quantize a (ROW_TILE, C) tile of row-major values."""
    x = x_ref[...]
    rows, cols = x.shape
    xb = x.reshape(rows, cols // s.block, s.block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    sexp = ref.block_scale_exp(amax, s.elem, s.scale)
    v = xb * ref._exp2i(-sexp)[..., None]
    if s.elem.is_float:
        q = ref.quantize_elem_float(v, s.elem)
        codes = ref.encode_elem_float(q, s.elem)
    else:
        q = ref.quantize_elem_int(v, s.elem)
        codes = ref.encode_elem_int(q, s.elem)
    codes_ref[...] = codes.reshape(rows, cols)
    scales_ref[...] = (sexp + s.scale.bias).astype(jnp.uint8)


def mx_quantize(x: jnp.ndarray, s: MxScheme):
    """Pallas MX quantize: f32[..., C] -> (codes u8[..., C], scales u8[..., C/B]).

    C must be a multiple of the scheme's block size.
    """
    orig_shape = x.shape
    cols = orig_shape[-1]
    assert cols % s.block == 0, (orig_shape, s.block)
    x2 = x.reshape(-1, cols)
    rows = x2.shape[0]
    tile = _row_tile(rows)
    grid = (rows // tile,)
    codes, scales = pl.pallas_call(
        functools.partial(_quantize_kernel, s=s),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile, cols), lambda i: (i, 0)),
            pl.BlockSpec((tile, cols // s.block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.uint8),
            jax.ShapeDtypeStruct((rows, cols // s.block), jnp.uint8),
        ],
        interpret=True,
    )(x2)
    return (
        codes.reshape(orig_shape),
        scales.reshape(orig_shape[:-1] + (cols // s.block,)),
    )


def _dequantize_kernel(codes_ref, scales_ref, out_ref, *, s: MxScheme):
    codes = codes_ref[...]
    rows, cols = codes.shape
    cb = codes.reshape(rows, cols // s.block, s.block)
    if s.elem.is_float:
        v = ref.decode_elem_float(cb, s.elem)
    else:
        v = ref.decode_elem_int(cb, s.elem)
    sexp = scales_ref[...].astype(jnp.int32) - s.scale.bias
    out_ref[...] = (v * ref._exp2i(sexp)[..., None]).reshape(rows, cols)


def mx_dequantize(codes: jnp.ndarray, scales: jnp.ndarray, s: MxScheme) -> jnp.ndarray:
    """Pallas MX dequantize, inverse of :func:`mx_quantize`."""
    orig_shape = codes.shape
    cols = orig_shape[-1]
    c2 = codes.reshape(-1, cols)
    s2 = scales.reshape(-1, cols // s.block)
    rows = c2.shape[0]
    tile = _row_tile(rows)
    grid = (rows // tile,)
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, cols), lambda i: (i, 0)),
            pl.BlockSpec((tile, cols // s.block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(c2, s2)
    return out.reshape(orig_shape)


def _dequant_reduce_kernel(codes_ref, scales_ref, out_ref, *, s: MxScheme, n: int):
    """Fused decompress-and-sum of the N gathered worker shards.

    codes: (N, ROW_TILE, C) tile. The sum runs in f32 accumulators in
    VMEM -- the reduce never materializes N dequantized tensors in HBM,
    which is the latency win over a separate dequant + torch.sum
    (paper Fig. 1b does decompress-then-sum; we fuse them).
    """
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    rows, cols = out_ref.shape
    for w in range(n):  # static unroll over TP degree
        cb = codes_ref[w].reshape(rows, cols // s.block, s.block)
        if s.elem.is_float:
            v = ref.decode_elem_float(cb, s.elem)
        else:
            v = ref.decode_elem_int(cb, s.elem)
        sexp = scales_ref[w].astype(jnp.int32) - s.scale.bias
        acc = acc + (v * ref._exp2i(sexp)[..., None]).reshape(rows, cols)
    out_ref[...] = acc


def mx_dequant_reduce(codes: jnp.ndarray, scales: jnp.ndarray, s: MxScheme) -> jnp.ndarray:
    """codes u8[N, ..., C], scales u8[N, ..., C/B] -> f32[..., C] summed."""
    n = codes.shape[0]
    orig_shape = codes.shape[1:]
    cols = orig_shape[-1]
    c2 = codes.reshape(n, -1, cols)
    s2 = scales.reshape(n, -1, cols // s.block)
    rows = c2.shape[1]
    tile = _row_tile(rows)
    grid = (rows // tile,)
    out = pl.pallas_call(
        functools.partial(_dequant_reduce_kernel, s=s, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, tile, cols), lambda i: (0, i, 0)),
            pl.BlockSpec((n, tile, cols // s.block), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(c2, s2)
    return out.reshape(orig_shape)


def mx_fake_quantize(x: jnp.ndarray, s: MxScheme) -> jnp.ndarray:
    """Pallas quantize -> dequantize round trip (error injection)."""
    codes, scales = mx_quantize(x, s)
    return mx_dequantize(codes, scales, s)
