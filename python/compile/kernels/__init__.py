"""L1 Pallas kernels + formats + jnp reference oracles."""

from . import formats, matmul, mx, ref, rmsnorm  # noqa: F401
