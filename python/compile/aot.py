"""AOT exporter: lower every TP stage to HLO text + write the manifest.

This is the compile-path boundary of the three-layer architecture:
python runs here ONCE (`make artifacts`), and never again — the rust
coordinator loads `artifacts/manifest.json`, compiles each HLO with the
PJRT CPU client on first use, and serves requests with no python in the
process.

Interchange format is HLO **text** (not serialized HloModuleProto): jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Exports, per model in configs.MODELS:
  stages    — embed / attn(tp) / mlp(tp) / final over every shape bucket
  comm ops  — reduce_add(tp) (uncompressed) and, for FUSED_SCHEMES,
              quantize + dequant_reduce_add(tp) (compressed, Fig. 1b)
  goldens   — MX codec vectors for the rust bit-exactness cross-check,
              and staged-forward logits for the rust integration test
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (
    BATCH_BUCKETS,
    FUSED_SCHEMES,
    MODELS,
    SEQ_BUCKETS,
    TP_DEGREES,
    ModelConfig,
)
from .kernels import ref
from .kernels.formats import BLOCK_SIZES, ELEM_FORMATS, SCALE_FORMATS, MxScheme, scheme

F32 = jnp.float32
I32 = jnp.int32
U8 = jnp.uint8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Exporter:
    def __init__(self, out_root: str):
        self.out_root = out_root
        self.entries = []
        self.n_lowered = 0

    def export(self, name: str, fn, in_specs, meta: dict):
        """Lower fn(*in_specs) to HLO text at artifacts/hlo/<name>.hlo.txt."""
        path = os.path.join("hlo", name + ".hlo.txt")
        full = os.path.join(self.out_root, path)
        os.makedirs(os.path.dirname(full), exist_ok=True)
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(full, "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_shape, (tuple, list)):
            out_shape = (out_shape,)
        self.entries.append(
            {
                "name": name,
                "path": path,
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
                ],
                "outputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in out_shape
                ],
                **meta,
            }
        )
        self.n_lowered += 1

    def write_manifest(self, extra: dict):
        manifest = {"version": 1, "artifacts": self.entries, **extra}
        with open(os.path.join(self.out_root, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)


# TP=2 is the primary serving degree (full bucket grid); other degrees are
# exported over a reduced grid (decode + the 128-token prefill bucket) to
# keep `make artifacts` fast -- Table 5's parallelism axis and the TTFT
# sweep only need those.
PRIMARY_TP = 2
REDUCED_BUCKETS = [(1, 1), (8, 1), (1, 128), (8, 128)]


def export_model_stages(ex: Exporter, cfg: ModelConfig):
    d, hd, t, v = cfg.d_model, cfg.head_dim, cfg.max_seq, cfg.vocab
    buckets = [(b, s) for b in BATCH_BUCKETS for s in SEQ_BUCKETS]

    for b, s in buckets:
        meta = {"model": cfg.name, "batch": b, "seq": s}
        ex.export(
            f"{cfg.name}/embed_b{b}_s{s}",
            M.embed_stage,
            [spec((b, s), I32), spec((v, d))],
            {"kind": "embed", **meta},
        )
        ex.export(
            f"{cfg.name}/final_b{b}_s{s}",
            functools.partial(M.final_stage, cfg),
            [spec((b, s, d)), spec((d,)), spec((d, v))],
            {"kind": "final", **meta},
        )
        for tp in TP_DEGREES:
            if tp != PRIMARY_TP and (b, s) not in REDUCED_BUCKETS:
                continue
            hn, fn_ = cfg.shard_heads(tp), cfg.shard_ff(tp)
            wspecs = [
                spec((d,)),
                spec((d, hn * hd)),
                spec((d, hn * hd)),
                spec((d, hn * hd)),
                spec((hn * hd, d)),
            ]
            if s > 1:
                # prefill: no KV history flows through PJRT (TTFT path)
                ex.export(
                    f"{cfg.name}/attn_prefill_tp{tp}_b{b}_s{s}",
                    functools.partial(M.attn_prefill_stage, cfg, tp),
                    [spec((b, s, d))] + wspecs + [spec((b,), I32)],
                    {"kind": "attn_prefill", "tp": tp, **meta},
                )
                if b == 1:
                    # chunked prefill: the KV-aware attn stage at (1, s)
                    # lets the coordinator slice a long prompt across
                    # decode steps (attn_stage is seq-generic — causal
                    # over the slice, history via the cache inputs)
                    ex.export(
                        f"{cfg.name}/attn_tp{tp}_b{b}_s{s}",
                        functools.partial(M.attn_stage, cfg, tp),
                        [spec((b, s, d))]
                        + wspecs
                        + [spec((b, hn, t, hd)), spec((b, hn, t, hd)), spec((b,), I32)],
                        {"kind": "attn", "tp": tp, **meta},
                    )
            else:
                # decode: history cache as input, new-token slice as output
                ex.export(
                    f"{cfg.name}/attn_tp{tp}_b{b}_s{s}",
                    functools.partial(M.attn_stage, cfg, tp),
                    [spec((b, s, d))]
                    + wspecs
                    + [spec((b, hn, t, hd)), spec((b, hn, t, hd)), spec((b,), I32)],
                    {"kind": "attn", "tp": tp, **meta},
                )
            ex.export(
                f"{cfg.name}/mlp_tp{tp}_b{b}_s{s}",
                functools.partial(M.mlp_stage, cfg, tp),
                [spec((b, s, d)), spec((d,)), spec((d, fn_)), spec((d, fn_)), spec((fn_, d))],
                {"kind": "mlp", "tp": tp, **meta},
            )
            ex.export(
                f"{cfg.name}/reduce_add_tp{tp}_b{b}_s{s}",
                M.reduce_add,
                [spec((b, s, d)), spec((tp, b, s, d))],
                {"kind": "reduce_add", "tp": tp, **meta},
            )

        # fused compressed-communication ops (paper Fig. 1b) for the
        # headline schemes; the full sweep uses the bit-exact rust codec.
        if (b, s) not in REDUCED_BUCKETS:
            continue
        for sname in FUSED_SCHEMES:
            sch = parse_scheme(sname)
            nb = d // sch.block
            ex.export(
                f"{cfg.name}/quant_{sname}_b{b}_s{s}",
                functools.partial(M.quantize_op, s=sch),
                [spec((b, s, d))],
                {"kind": "quantize", "scheme": sname, **meta},
            )
            for tp in (2, 4):
                ex.export(
                    f"{cfg.name}/dqra_{sname}_tp{tp}_b{b}_s{s}",
                    functools.partial(M.dequant_reduce_add, s=sch),
                    [
                        spec((b, s, d)),
                        spec((tp, b, s, d), U8),
                        spec((tp, b, s, nb), U8),
                    ],
                    {"kind": "dequant_reduce_add", "scheme": sname, "tp": tp, **meta},
                )


def parse_scheme(name: str) -> MxScheme:
    """'fp4_e2m1_b32_e8m0' -> MxScheme."""
    parts = name.split("_")
    scale = parts[-1]
    block = int(parts[-2][1:])
    elem = "_".join(parts[:-2])
    return scheme(elem, block, scale)


def export_codec_goldens(out_root: str):
    """Bit-exactness vectors for the rust MX codec, all schemes."""
    gdir = os.path.join(out_root, "golden", "codec")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(2024)
    base = rng.standard_normal((64, 96)).astype(np.float32)
    spreadv = np.exp(rng.standard_normal((64, 96)) * 3).astype(np.float32)
    x = base * spreadv
    # salt in exact zeros, tiny and huge values (edge cases)
    x[0, :8] = 0.0
    x[1, 0] = 3e38
    x[2, 0] = 1e-38
    np.save(os.path.join(gdir, "x.npy"), x)
    index = []
    for en in ELEM_FORMATS:
        for blk in BLOCK_SIZES:
            for sn in SCALE_FORMATS:
                sch = scheme(en, blk, sn)
                codes, scales = ref.quantize_ref(jnp.asarray(x), sch)
                deq = ref.dequantize_ref(codes, scales, sch)
                tag = sch.name
                np.save(os.path.join(gdir, f"{tag}.codes.npy"), np.asarray(codes))
                np.save(os.path.join(gdir, f"{tag}.scales.npy"), np.asarray(scales))
                np.save(os.path.join(gdir, f"{tag}.deq.npy"), np.asarray(deq))
                index.append(tag)
    with open(os.path.join(gdir, "index.json"), "w") as f:
        json.dump({"schemes": index, "x": "x.npy"}, f, indent=1)


def export_forward_goldens(out_root: str, weights_root: str):
    """Staged-forward logits for the rust end-to-end integration test."""
    gdir = os.path.join(out_root, "golden", "forward")
    os.makedirs(gdir, exist_ok=True)
    name = "nano"
    cfg = MODELS[name]
    wdir = os.path.join(weights_root, name)
    if not os.path.exists(os.path.join(wdir, "train_log.json")):
        print("forward goldens: weights missing, skipped")
        return
    p = {
        os.path.splitext(f)[0]: jnp.asarray(np.load(os.path.join(wdir, f)))
        for f in os.listdir(wdir)
        if f.endswith(".npy")
    }
    rng = np.random.default_rng(5)
    tokens = rng.integers(0, cfg.vocab, size=(1, 16)).astype(np.int32)
    np.save(os.path.join(gdir, "tokens.npy"), tokens)
    logits = M.tp_forward(cfg, p, jnp.asarray(tokens), tp=2, scheme=None)
    np.save(os.path.join(gdir, "logits_tp2.npy"), np.asarray(logits))
    sch = parse_scheme("fp4_e2m1_b32_e8m0")
    logits_q = M.tp_forward(cfg, p, jnp.asarray(tokens), tp=2, scheme=sch)
    np.save(os.path.join(gdir, "logits_tp2_fp4.npy"), np.asarray(logits_q))
    with open(os.path.join(gdir, "meta.json"), "w") as f:
        json.dump({"model": name, "tp": 2, "scheme": "fp4_e2m1_b32_e8m0"}, f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--skip-stages", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    ex = Exporter(args.out)
    if not args.skip_stages:
        for mn in args.models.split(","):
            tm = time.time()
            export_model_stages(ex, MODELS[mn])
            print(f"[aot] {mn}: {ex.n_lowered} artifacts so far ({time.time()-tm:.0f}s)", flush=True)
    ex.write_manifest(
        {
            "models": {
                n: {
                    "vocab": c.vocab,
                    "d_model": c.d_model,
                    "n_layers": c.n_layers,
                    "n_heads": c.n_heads,
                    "head_dim": c.head_dim,
                    "d_ff": c.d_ff,
                    "max_seq": c.max_seq,
                    "params": c.params,
                }
                for n, c in MODELS.items()
            },
            "tp_degrees": list(TP_DEGREES),
            "seq_buckets": list(SEQ_BUCKETS),
            "batch_buckets": list(BATCH_BUCKETS),
            "fused_schemes": list(FUSED_SCHEMES),
        }
    )
    export_codec_goldens(args.out)
    export_forward_goldens(args.out, os.path.join(args.out, "weights"))
    print(f"[aot] done: {ex.n_lowered} HLO artifacts in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
