"""L2 model tests: shapes, TP-stage equivalence, KV-cache decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import MODELS
from compile.kernels.formats import scheme


@pytest.fixture(scope="module")
def nano():
    cfg = MODELS["nano"]
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, p


def test_param_count_matches_config(nano):
    cfg, p = nano
    n = sum(int(np.prod(a.shape)) for a in p.values())
    assert n == cfg.params


def test_full_forward_shape(nano):
    cfg, p = nano
    toks = jnp.zeros((2, 8), jnp.int32)
    out = M.full_forward(cfg, p, toks)
    assert out.shape == (2, 8, cfg.vocab)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("tp", [1, 2, 4, 8])
def test_tp_forward_matches_full(nano, tp):
    """The staged TP decomposition must reproduce the monolithic model.

    This is the Fig. 1a correctness statement: column/row-parallel shard
    outputs, all-gathered and reduced, equal the unsharded computation.
    """
    cfg, p = nano
    rng = np.random.default_rng(tp)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)).astype(np.int32))
    full = M.full_forward(cfg, p, toks)
    staged = M.tp_forward(cfg, p, toks, tp=tp)
    np.testing.assert_allclose(np.array(staged), np.array(full), rtol=1e-3, atol=2e-4)


def test_tp_forward_quantized_close(nano):
    """Compressed communication must stay close to (not equal) the exact
    output -- and closer for FP5 than FP3 (Table 1 ordering)."""
    cfg, p = nano
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)).astype(np.int32))
    exact = np.array(M.tp_forward(cfg, p, toks, tp=2))
    errs = {}
    for en in ("fp5_e2m2", "fp4_e2m1", "fp3_e1m1"):
        q = np.array(M.tp_forward(cfg, p, toks, tp=2, scheme=scheme(en, 32)))
        errs[en] = float(np.abs(q - exact).mean())
        assert np.isfinite(q).all()
    assert errs["fp5_e2m2"] < errs["fp4_e2m1"] < errs["fp3_e1m1"]


def test_attn_stage_kv_cache_decode(nano):
    """Prefill S tokens at once == prefill S-1 then decode 1 with the cache.

    This pins the contract between attn_prefill_stage (returns k/v slices)
    and attn_stage (consumes the rust-maintained history cache).
    """
    cfg, p = nano
    tp, rank, b, s = 2, 0, 1, 8
    sp = M.shard_params(cfg, p, tp, rank)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)).astype(np.float32))
    hn, hd, t = cfg.shard_heads(tp), cfg.head_dim, cfg.max_seq
    w = lambda n: sp[f"l0.{n}"]
    args = (w("attn_norm"), w("wq"), w("wk"), w("wv"), w("wo"))

    zero = jnp.zeros((b,), jnp.int32)
    full_out, _, _ = M.attn_prefill_stage(cfg, tp, x, *args, zero)

    pre_out, k_sl, v_sl = M.attn_prefill_stage(cfg, tp, x[:, : s - 1], *args, zero)
    # mirror the coordinator's cache maintenance: write slices at pos 0
    kc = jnp.zeros((b, hn, t, hd), jnp.float32).at[:, :, : s - 1].set(k_sl)
    vc = jnp.zeros((b, hn, t, hd), jnp.float32).at[:, :, : s - 1].set(v_sl)
    dec_out, k1, v1 = M.attn_stage(
        cfg, tp, x[:, s - 1 :], *args, kc, vc, jnp.full((b,), s - 1, jnp.int32)
    )
    assert k1.shape == (b, hn, 1, hd) and v1.shape == (b, hn, 1, hd)

    np.testing.assert_allclose(
        np.array(full_out[:, s - 1 :]), np.array(dec_out), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.array(full_out[:, : s - 1]), np.array(pre_out), rtol=1e-4, atol=1e-5
    )


def test_shard_params_partition(nano):
    """Shards tile the full weight matrices exactly (no overlap, no gap)."""
    cfg, p = nano
    for tp in (2, 4):
        shards = [M.shard_params(cfg, p, tp, r) for r in range(tp)]
        wq = np.concatenate([np.array(s["l0.wq"]) for s in shards], axis=1)
        np.testing.assert_array_equal(wq, np.array(p["l0.wq"]))
        wo = np.concatenate([np.array(s["l0.wo"]) for s in shards], axis=0)
        np.testing.assert_array_equal(wo, np.array(p["l0.wo"]))
        wd = np.concatenate([np.array(s["l0.w_down"]) for s in shards], axis=0)
        np.testing.assert_array_equal(wd, np.array(p["l0.w_down"]))


def test_rope_positions_shift_consistency(nano):
    cfg, _ = nano
    cos0, sin0 = M.rope_angles(cfg, jnp.arange(4) + 3)
    cos1, sin1 = M.rope_angles(cfg, jnp.arange(3, 7))
    np.testing.assert_allclose(np.array(cos0), np.array(cos1))
    np.testing.assert_allclose(np.array(sin0), np.array(sin1))


def test_corpus_deterministic_and_split():
    from compile import corpus

    a1, b1 = corpus.train_test(20_000, 5_000)
    a2, b2 = corpus.train_test(20_000, 5_000)
    assert a1 == a2 and b1 == b2
    assert a1[:2000] != b1[:2000]  # disjoint streams
    assert len(a1) >= 20_000 and len(b1) >= 5_000
    # mostly-ASCII natural text (byte-level models see UTF-8 bytes)
    ascii_frac = sum(ord(c) < 128 for c in a1[:5000]) / 5000
    assert ascii_frac > 0.97
