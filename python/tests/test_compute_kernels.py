"""Pallas matmul / rmsnorm kernels vs jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, rmsnorm


@pytest.mark.parametrize(
    "m,k,n",
    [(8, 16, 8), (128, 128, 128), (48, 96, 160), (256, 128, 64), (1, 64, 32), (130, 70, 90)],
)
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = np.array(matmul.matmul(a, b))
    want = np.array(ref.matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    np.testing.assert_allclose(
        np.array(matmul.matmul(a, b)),
        np.array(ref.matmul_ref(a, b)),
        rtol=1e-4,
        atol=1e-4 * max(1, k // 8),
    )


def test_matmul_flat_batched():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 5, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    got = np.array(matmul.matmul_flat(x, w))
    want = np.array(jnp.einsum("bsk,kn->bsn", x, w))
    assert got.shape == (2, 5, 16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows,d", [(1, 16), (128, 256), (37, 64), (300, 128)])
def test_rmsnorm_matches_ref(rows, d):
    rng = np.random.default_rng(rows + d)
    x = jnp.asarray(rng.standard_normal((rows, d)).astype(np.float32) * 3)
    g = jnp.asarray(rng.standard_normal((d,)).astype(np.float32))
    np.testing.assert_allclose(
        np.array(rmsnorm.rmsnorm(x, g)),
        np.array(ref.rmsnorm_ref(x, g)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_rmsnorm_scale_invariance():
    """RMSNorm(cx) == RMSNorm(x) for c > 0 (up to eps effects)."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    g = jnp.ones((64,), jnp.float32)
    a = np.array(rmsnorm.rmsnorm(x, g))
    b = np.array(rmsnorm.rmsnorm(x * 100.0, g))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)
