"""Pallas MX kernels vs the pure-jnp oracle: the CORE correctness signal.

Hypothesis sweeps shapes, dtypes, block sizes, scale widths and value
distributions; every case asserts the Pallas kernel output is *bit-equal*
to ref.py (codes, scales) and that dequantization round-trips within the
format's worst-case error bound.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mx, ref
from compile.kernels.formats import (
    BLOCK_SIZES,
    ELEM_FORMATS,
    SCALE_FORMATS,
    MxScheme,
    scheme,
)

ALL_SCHEMES = [
    scheme(e, b, s)
    for e in ELEM_FORMATS
    for b in BLOCK_SIZES
    for s in ("e8m0", "e5m0")
]
KEY_SCHEMES = [
    scheme("fp4_e2m1", 32, "e8m0"),
    scheme("fp5_e2m2", 32, "e8m0"),
    scheme("fp3_e1m1", 8, "e8m0"),
    scheme("int4", 16, "e5m0"),
]


def _rand(rng, shape, spread=4.0):
    """Activations with outliers: normal * lognormal exponent spread."""
    base = rng.standard_normal(shape).astype(np.float32)
    scale = np.exp(rng.standard_normal(shape) * spread / 2).astype(np.float32)
    return base * scale


# --------------------------------------------------------------------------
# bit-exactness pallas == ref
# --------------------------------------------------------------------------


@pytest.mark.parametrize("s", ALL_SCHEMES, ids=lambda s: s.name)
def test_pallas_matches_ref_bitexact(s: MxScheme):
    rng = np.random.default_rng(hash(s.name) % 2**31)
    x = jnp.asarray(_rand(rng, (16, 4 * s.block)))
    c_ref, sc_ref = ref.quantize_ref(x, s)
    c_pal, sc_pal = mx.mx_quantize(x, s)
    np.testing.assert_array_equal(np.array(c_ref), np.array(c_pal))
    np.testing.assert_array_equal(np.array(sc_ref), np.array(sc_pal))
    d_ref = ref.dequantize_ref(c_ref, sc_ref, s)
    d_pal = mx.mx_dequantize(c_pal, sc_pal, s)
    np.testing.assert_array_equal(np.array(d_ref), np.array(d_pal))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 40),
    nblk=st.integers(1, 8),
    elem=st.sampled_from(sorted(ELEM_FORMATS)),
    block=st.sampled_from(BLOCK_SIZES),
    sbits=st.sampled_from(sorted(SCALE_FORMATS)),
    seed=st.integers(0, 2**16),
    spread=st.floats(0.1, 8.0),
)
def test_pallas_matches_ref_hypothesis(rows, nblk, elem, block, sbits, seed, spread):
    s = scheme(elem, block, sbits)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(_rand(rng, (rows, nblk * block), spread))
    c_ref, sc_ref = ref.quantize_ref(x, s)
    c_pal, sc_pal = mx.mx_quantize(x, s)
    np.testing.assert_array_equal(np.array(c_ref), np.array(c_pal))
    np.testing.assert_array_equal(np.array(sc_ref), np.array(sc_pal))
    np.testing.assert_array_equal(
        np.array(ref.dequantize_ref(c_ref, sc_ref, s)),
        np.array(mx.mx_dequantize(c_pal, sc_pal, s)),
    )


# --------------------------------------------------------------------------
# quantization-error invariants
# --------------------------------------------------------------------------


@pytest.mark.parametrize("s", KEY_SCHEMES, ids=lambda s: s.name)
def test_roundtrip_error_bound(s: MxScheme):
    """Per-block relative error is bounded by the format's ulp at amax.

    With shared exponent at the amax binade, the worst-case absolute
    error within a block is ~0.5 ulp of the top binade (float) or 0.5
    scale step (int), i.e. amax * 2^-(mbits) for floats.
    """
    rng = np.random.default_rng(7)
    x = _rand(rng, (32, 8 * s.block), spread=2.0)
    d = np.array(ref.fake_quantize_ref(jnp.asarray(x), s))
    xb = x.reshape(-1, s.block)
    db = d.reshape(-1, s.block)
    amax = np.abs(xb).max(axis=1)
    if s.elem.is_float:
        bound = amax * 2.0 ** (-s.elem.mbits) * 1.01
    else:
        bound = amax / s.elem.int_qmax * 1.01
    err = np.abs(xb - db).max(axis=1)
    assert (err <= np.maximum(bound, 1e-30)).all()


def test_exact_values_survive():
    """Values already on the grid must pass through unchanged."""
    s = scheme("fp4_e2m1", 8)
    # E2M1 grid: 0, 0.5, 1, 1.5, 2, 3, 4, 6 (x scale)
    x = jnp.asarray(np.array([[0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]], np.float32))
    d = np.array(ref.fake_quantize_ref(x, s))
    np.testing.assert_array_equal(d, np.array(x))
    # negatives too
    d2 = np.array(ref.fake_quantize_ref(-x, s))
    np.testing.assert_array_equal(d2, -np.array(x))


def test_zero_block():
    for s in KEY_SCHEMES:
        x = jnp.zeros((4, 2 * s.block), jnp.float32)
        c, sc = ref.quantize_ref(x, s)
        d = np.array(ref.dequantize_ref(c, sc, s))
        np.testing.assert_array_equal(d, 0.0)


def test_saturation_on_outlier_block():
    """An outlier dominates its block's scale; everything clamps, nothing is inf/nan."""
    s = scheme("fp4_e2m1", 8, "e8m0")
    x = np.full((1, 8), 1.0, np.float32)
    x[0, 3] = 3.0e38  # near f32 max
    d = np.array(ref.fake_quantize_ref(jnp.asarray(x), s))
    assert np.isfinite(d).all()
    assert d[0, 3] > 0


def test_scale_clamp_small_values():
    """Tiny blocks clamp to the scale format's emin (Table 5 scale-bits axis)."""
    big = scheme("fp4_e2m1", 8, "e8m0")
    small = scheme("fp4_e2m1", 8, "e4m0")
    x = jnp.asarray(np.full((1, 8), 2.0**-30, np.float32))
    d_big = np.array(ref.fake_quantize_ref(x, big))
    d_small = np.array(ref.fake_quantize_ref(x, small))
    # e8m0 can represent 2^-32 scales; e4m0 bottoms out at 2^-7
    assert np.abs(d_big - np.array(x)).max() < 2.0**-31
    assert (d_small == 0).all() or np.abs(d_small - np.array(x)).max() > np.abs(d_big - np.array(x)).max()


@pytest.mark.parametrize("s", KEY_SCHEMES, ids=lambda s: s.name)
def test_error_monotone_in_block_size(s: MxScheme):
    """Averaged over many blocks, larger blocks cannot beat smaller ones
    (coarser scale granularity) -- the paper's block-size axis."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(_rand(rng, (64, 96), spread=4.0))
    errs = []
    for b in (8, 16, 32):
        sb = MxScheme(s.elem, s.scale, b)
        d = ref.fake_quantize_ref(x, sb)
        errs.append(float(jnp.mean((d - x) ** 2)))
    assert errs[0] <= errs[1] * 1.05 and errs[1] <= errs[2] * 1.05


def test_effective_bits_accounting():
    assert scheme("fp4_e2m1", 32, "e8m0").effective_bits == pytest.approx(4.25)
    assert scheme("fp4_e2m1", 8, "e8m0").effective_bits == pytest.approx(5.0)
    assert scheme("fp5_e2m2", 32, "e8m0").effective_bits == pytest.approx(5.25)
    assert scheme("int4", 16, "e5m0").effective_bits == pytest.approx(4.3125)
    # wire bytes bit-packing
    s = scheme("fp4_e2m1", 32, "e8m0")
    assert s.wire_bytes(32) == (32 * 4 + 8 + 7) // 8
    assert s.compression_ratio == pytest.approx(16 / 4.25)


# --------------------------------------------------------------------------
# fused dequant+reduce (the Fig 1b op)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 4, 8])
def test_dequant_reduce_matches_ref(n):
    s = scheme("fp4_e2m1", 32)
    rng = np.random.default_rng(n)
    x = jnp.asarray(_rand(rng, (n, 16, 2 * s.block)))
    c, sc = mx.mx_quantize(x, s)
    out_pal = mx.mx_dequant_reduce(c, sc, s)
    out_ref = ref.dequant_reduce_ref(c, sc, s)
    np.testing.assert_allclose(np.array(out_pal), np.array(out_ref), rtol=0, atol=1e-5)


def test_dequant_reduce_equals_sum_of_dequant():
    s = scheme("fp5_e2m2", 16)
    rng = np.random.default_rng(3)
    x = jnp.asarray(_rand(rng, (4, 8, 4 * s.block)))
    c, sc = mx.mx_quantize(x, s)
    fused = np.array(mx.mx_dequant_reduce(c, sc, s))
    manual = sum(np.array(mx.mx_dequantize(c[i], sc[i], s)) for i in range(4))
    np.testing.assert_allclose(fused, manual, rtol=0, atol=1e-5)
