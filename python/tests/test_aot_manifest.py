"""AOT exporter contract tests: validate artifacts/manifest.json against
the configs the rust runtime depends on (no re-export needed — pure
reads; skipped when `make artifacts` has not run)."""

import json
import os

import pytest

from compile.configs import BATCH_BUCKETS, FUSED_SCHEMES, MODELS, SEQ_BUCKETS, TP_DEGREES
from compile.aot import PRIMARY_TP, REDUCED_BUCKETS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_names_unique_and_files_exist(manifest):
    names = [a["name"] for a in manifest["artifacts"]]
    assert len(names) == len(set(names))
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["path"])), a["path"]


def test_models_section_matches_configs(manifest):
    for name, cfg in MODELS.items():
        m = manifest["models"][name]
        assert m["d_model"] == cfg.d_model
        assert m["n_layers"] == cfg.n_layers
        assert m["params"] == cfg.params
        assert m["max_seq"] == cfg.max_seq


def test_primary_tp_has_full_bucket_grid(manifest):
    """The serving TP degree must cover every (batch, seq) bucket for
    every stage kind the engine calls."""
    arts = manifest["artifacts"]
    for model in MODELS:
        for b in BATCH_BUCKETS:
            for s in SEQ_BUCKETS:
                kinds = {"embed", "final", "mlp", "reduce_add"}
                kinds.add("attn" if s == 1 else "attn_prefill")
                for kind in kinds:
                    found = [
                        a
                        for a in arts
                        if a["model"] == model
                        and a["kind"] == kind
                        and a["batch"] == b
                        and a["seq"] == s
                        and (a.get("tp", PRIMARY_TP) in (PRIMARY_TP, 0) or kind in ("embed", "final"))
                    ]
                    assert found, f"{model}/{kind} missing bucket b{b} s{s}"


def test_reduced_buckets_cover_all_tp_degrees(manifest):
    arts = manifest["artifacts"]
    for model in MODELS:
        for tp in TP_DEGREES:
            for (b, s) in REDUCED_BUCKETS:
                kind = "attn" if s == 1 else "attn_prefill"
                found = [
                    a
                    for a in arts
                    if a["model"] == model and a["kind"] == kind and a.get("tp") == tp
                    and a["batch"] == b and a["seq"] == s
                ]
                assert found, f"{model} tp{tp} missing {kind} b{b} s{s}"


def test_attn_prefill_signature_shapes(manifest):
    """Input/output shapes recorded in the manifest must match the stage
    contract the rust engine builds literals for."""
    for a in manifest["artifacts"]:
        if a["kind"] != "attn_prefill":
            continue
        cfg = MODELS[a["model"]]
        b, s, tp = a["batch"], a["seq"], a["tp"]
        hn = cfg.n_heads // tp
        ins = [tuple(i["shape"]) for i in a["inputs"]]
        assert ins[0] == (b, s, cfg.d_model)  # x
        assert ins[1] == (cfg.d_model,)  # norm
        assert ins[2] == (cfg.d_model, hn * cfg.head_dim)  # wq
        assert ins[-1] == (b,)  # pos vector
        outs = [tuple(o["shape"]) for o in a["outputs"]]
        assert outs[0] == (b, s, cfg.d_model)  # partial
        assert outs[1] == (b, hn, s, cfg.head_dim)  # k slice
        assert outs[2] == (b, hn, s, cfg.head_dim)  # v slice


def test_decode_attn_takes_cache(manifest):
    """KV-aware attn exists at seq 1 (decode) and, for chunked prefill,
    at batch 1 over the wider seq buckets — every instance takes the
    full-length cache as input."""
    seen_chunk = False
    for a in manifest["artifacts"]:
        if a["kind"] != "attn":
            continue
        cfg = MODELS[a["model"]]
        assert a["seq"] in SEQ_BUCKETS
        if a["seq"] > 1:
            assert a["batch"] == 1, "chunked-prefill attn is batch-1 only"
            seen_chunk = True
        ins = [tuple(i["shape"]) for i in a["inputs"]]
        hn = cfg.n_heads // a["tp"]
        assert (a["batch"], hn, cfg.max_seq, cfg.head_dim) in ins  # k_cache
    assert seen_chunk, "no chunked-prefill attn artifacts exported"


def test_chunked_prefill_attn_covers_primary_tp_grid(manifest):
    """The live coordinator only enables chunked prefill when every
    prefill bucket at or below the chunk size has a KV-aware attn
    executable; the primary TP degree must export the full batch-1
    seq grid."""
    arts = manifest["artifacts"]
    for model in MODELS:
        for s in SEQ_BUCKETS:
            if s <= 1:
                continue
            found = [
                a
                for a in arts
                if a["model"] == model and a["kind"] == "attn"
                and a.get("tp") == PRIMARY_TP and a["batch"] == 1 and a["seq"] == s
            ]
            assert found, f"{model} tp{PRIMARY_TP} missing chunk attn s{s}"


def test_fused_schemes_exported(manifest):
    arts = manifest["artifacts"]
    for model in MODELS:
        for scheme in FUSED_SCHEMES:
            q = [a for a in arts if a["model"] == model and a["kind"] == "quantize" and a["scheme"] == scheme]
            d = [a for a in arts if a["model"] == model and a["kind"] == "dequant_reduce_add" and a["scheme"] == scheme]
            assert q and d, f"{model}/{scheme} fused ops missing"
            # quantize outputs: codes (uint8, same shape) + scales
            o = q[0]["outputs"]
            assert o[0]["dtype"] == "uint8"
            assert o[1]["dtype"] == "uint8"


def test_golden_dirs_present():
    if not os.path.exists(os.path.join(ART, "manifest.json")):
        pytest.skip("run `make artifacts` first")
    assert os.path.exists(os.path.join(ART, "golden/codec/index.json"))
    assert os.path.exists(os.path.join(ART, "golden/forward/tokens.npy"))
    with open(os.path.join(ART, "golden/codec/index.json")) as f:
        idx = json.load(f)
    # full scheme grid: 9 elem formats x 3 blocks x 5 scale widths
    assert len(idx["schemes"]) == 9 * 3 * 5


def test_weights_and_corpus_present():
    wroot = os.path.join(ART, "weights")
    if not os.path.exists(wroot):
        pytest.skip("run `make artifacts` first")
    for model in MODELS:
        d = os.path.join(wroot, model)
        assert os.path.exists(os.path.join(d, "train_log.json")), model
        with open(os.path.join(d, "train_log.json")) as f:
            log = json.load(f)
        # training must actually have reduced the loss
        assert log["loss"][0] > 2 * log["loss"][-1], (model, log["loss"][:1], log["loss"][-1:])
    assert os.path.getsize(os.path.join(wroot, "corpus_train.txt")) > 100_000
    assert os.path.getsize(os.path.join(wroot, "corpus_test.txt")) > 10_000
